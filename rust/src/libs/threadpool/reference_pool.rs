//! The PR 4–8 mutex-based Eigen-style pool, preserved verbatim as the
//! measured baseline for the lock-free substrate (the
//! `simulate_reference` / `with_reference_loop` pattern of PRs 6–7).
//!
//! Per-thread `Mutex<VecDeque>` deques with round-robin placement and
//! random-start stealing, plus a **global idle mutex acquired on every
//! `execute`** — the serialisation the rebuilt [`super::EigenPool`]
//! removes. `BENCH_threadpool.json`'s `fastpath-vs-reference` cases
//! measure the two planes against each other; nothing in the serving
//! or tuning stack runs on this pool except by explicit choice in
//! benches and tests.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::prng::Prng;

use super::{Task, TaskPool};

struct Shared {
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// parked-worker wake-up
    idle: Mutex<usize>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// round-robin submission cursor
    next: AtomicUsize,
    /// outstanding task count (lets workers park safely)
    pending: AtomicUsize,
}

/// The mutex-based work-stealing pool (reference plane).
pub struct ReferencePool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ReferencePool {
    /// Spawn `n` workers, each owning a deque.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            deques: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            idle: Mutex::new(0),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("reference-pool-{i}"))
                    .spawn(move || worker(s, i))
                    .expect("spawn")
            })
            .collect();
        ReferencePool { shared, workers }
    }
}

const SPIN_TRIES: usize = 64;

fn try_pop(shared: &Shared, me: usize, rng: &mut Prng) -> Option<Task> {
    // own deque first (LIFO end — cache-warm)
    if let Some(t) = shared.deques[me].lock().unwrap().pop_back() {
        return Some(t);
    }
    // then steal a victim's FIFO end
    let n = shared.deques.len();
    let start = rng.below(n.max(1));
    for off in 0..n {
        let v = (start + off) % n;
        if v == me {
            continue;
        }
        if let Some(t) = shared.deques[v].lock().unwrap().pop_front() {
            return Some(t);
        }
    }
    None
}

fn worker(shared: Arc<Shared>, me: usize) {
    let mut rng = Prng::new(me as u64 ^ 0x5eed);
    loop {
        // spin phase
        let mut got = None;
        for _ in 0..SPIN_TRIES {
            if shared.pending.load(Ordering::Acquire) > 0 {
                if let Some(t) = try_pop(&shared, me, &mut rng) {
                    got = Some(t);
                    break;
                }
            }
            std::hint::spin_loop();
        }
        if let Some(t) = got {
            shared.pending.fetch_sub(1, Ordering::AcqRel);
            t();
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire)
            && shared.pending.load(Ordering::Acquire) == 0
        {
            return;
        }
        // park phase
        let mut idle = shared.idle.lock().unwrap();
        if shared.pending.load(Ordering::Acquire) > 0
            || shared.shutdown.load(Ordering::Acquire)
        {
            continue; // re-check without sleeping
        }
        *idle += 1;
        // The timeout is a belt-and-braces re-check, not the wakeup
        // path: submitters bump `pending` before taking the `idle` lock
        // and notifying, so a sleeping worker cannot miss work. 100 ms
        // keeps a *persistent* pool close to 0% CPU while idle.
        let (guard, _timeout) = shared
            .cv
            .wait_timeout(idle, std::time::Duration::from_millis(100))
            .unwrap();
        idle = guard;
        *idle -= 1;
    }
}

impl TaskPool for ReferencePool {
    fn execute(&self, task: Task) {
        let n = self.shared.deques.len();
        let slot = self.shared.next.fetch_add(1, Ordering::Relaxed) % n;
        self.shared.deques[slot].lock().unwrap().push_back(task);
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        // wake at most one parked worker — through the global idle lock
        // on every submission, which is exactly what the lock-free pool
        // is measured against
        let idle = self.shared.idle.lock().unwrap();
        if *idle > 0 {
            self.shared.cv.notify_one();
        }
    }

    fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ReferencePool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_plane_runs_all_tasks() {
        let pool = ReferencePool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = super::super::WaitGroup::new(64);
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            let h = wg.handle();
            pool.execute(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
                h.done();
            }));
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }
}
