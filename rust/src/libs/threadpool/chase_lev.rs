//! Chase–Lev work-stealing deque (the queue inside Eigen's pool).
//!
//! Single producer, multiple consumers: the owning worker pushes and
//! takes at the *bottom* (LIFO — its own most recent task is the
//! cache-warm one), thieves steal at the *top* (FIFO — the oldest task
//! is the one least likely to be in the owner's cache anyway). The
//! implementation follows Chase & Lev ("Dynamic Circular Work-Stealing
//! Deque", SPAA'05) with the C11 orderings of Lê et al. ("Correct and
//! Efficient Work-Stealing for Weak Memory Models", PPoPP'13):
//! `Acquire`/`Release` on the index pair plus the canonical `SeqCst`
//! fences/CAS on the take-vs-steal race over the last element.
//!
//! The ring buffer grows by doubling when the owner pushes into a full
//! ring. Retired rings are kept alive (owner-side, behind a mutex that
//! only the grow path touches) until the deque itself drops, so a
//! thief that loaded a stale ring pointer can still read through it:
//! the element bits at any logical index are identical in every ring
//! that contains that index, and the `top` CAS decides uniquely who
//! consumes it.

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, Ordering};
use std::sync::{Arc, Mutex};

/// Initial ring capacity (grows by doubling; must be a power of two).
const INITIAL_CAP: usize = 64;

struct Ring<T> {
    mask: usize,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
}

impl<T> Ring<T> {
    fn alloc(cap: usize) -> *mut Ring<T> {
        assert!(cap.is_power_of_two());
        let slots = (0..cap)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Box::into_raw(Box::new(Ring { mask: cap - 1, slots }))
    }

    fn cap(&self) -> usize {
        self.mask + 1
    }

    /// Copy the element bits at logical index `i` out of the ring.
    ///
    /// # Safety
    /// The caller must own logical index `i` (bottom reservation or a
    /// successful `top` CAS) before *using* the value; a speculative
    /// read that loses the race must be `mem::forget`-ten, not dropped.
    unsafe fn read(&self, i: isize) -> T {
        debug_assert!(i >= 0);
        std::ptr::read((*self.slots[i as usize & self.mask].get()).as_ptr())
    }

    /// Write the element bits at logical index `i` (owner only; never
    /// drops a previous occupant — slots are `MaybeUninit`).
    ///
    /// # Safety
    /// Owner-thread only, and slot `i & mask` must not hold a live
    /// element the deque still hands out.
    unsafe fn write(&self, i: isize, v: T) {
        debug_assert!(i >= 0);
        (*self.slots[i as usize & self.mask].get()).write(v);
    }
}

struct Inner<T> {
    /// Steal cursor — only ever incremented (no ABA).
    top: AtomicIsize,
    /// Owner cursor — push increments, take decrements.
    bottom: AtomicIsize,
    ring: AtomicPtr<Ring<T>>,
    /// Rings retired by growth, freed when the deque drops. Only the
    /// owner's grow path pushes here, so the mutex is uncontended.
    retired: Mutex<Vec<*mut Ring<T>>>,
}

unsafe impl<T: Send> Send for Inner<T> {}
unsafe impl<T: Send> Sync for Inner<T> {}

impl<T> Drop for Inner<T> {
    fn drop(&mut self) {
        // Sole remaining handle: plain loads are fine.
        let t = *self.top.get_mut();
        let b = *self.bottom.get_mut();
        let ring = *self.ring.get_mut();
        unsafe {
            for i in t..b {
                drop((*ring).read(i));
            }
            drop(Box::from_raw(ring));
            for r in self.retired.get_mut().unwrap().drain(..) {
                drop(Box::from_raw(r));
            }
        }
    }
}

/// Owner handle: `push`/`take` at the bottom. `Send` (it moves into the
/// worker thread) but deliberately `!Sync` — the Chase–Lev owner end is
/// single-threaded by contract.
pub struct Worker<T> {
    inner: Arc<Inner<T>>,
    _not_sync: PhantomData<std::cell::Cell<()>>,
}

/// Thief handle: `steal` at the top. Clone freely across threads.
pub struct Stealer<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Stealer<T> {
    fn clone(&self) -> Self {
        Stealer { inner: Arc::clone(&self.inner) }
    }
}

/// Outcome of a steal attempt.
pub enum Steal<T> {
    /// The deque was observably empty.
    Empty,
    /// Lost a race with the owner or another thief; worth retrying.
    Retry,
    /// Got the oldest element.
    Success(T),
}

/// Create a deque, returning the owner and a thief handle.
pub fn deque<T: Send>() -> (Worker<T>, Stealer<T>) {
    let inner = Arc::new(Inner {
        top: AtomicIsize::new(0),
        bottom: AtomicIsize::new(0),
        ring: AtomicPtr::new(Ring::alloc(INITIAL_CAP)),
        retired: Mutex::new(Vec::new()),
    });
    (Worker { inner: Arc::clone(&inner), _not_sync: PhantomData }, Stealer { inner })
}

impl<T: Send> Worker<T> {
    /// Push at the bottom (owner thread only). Grows the ring when full.
    pub fn push(&self, value: T) {
        let inner = &*self.inner;
        let b = inner.bottom.load(Ordering::Relaxed);
        let t = inner.top.load(Ordering::Acquire);
        let mut ring = inner.ring.load(Ordering::Relaxed);
        unsafe {
            if b - t >= (*ring).cap() as isize {
                ring = self.grow(ring, b, t);
            }
            (*ring).write(b, value);
        }
        // Publish the element before the new bottom becomes visible.
        inner.bottom.store(b + 1, Ordering::Release);
    }

    /// Pop at the bottom (owner thread only) — LIFO relative to `push`.
    pub fn take(&self) -> Option<T> {
        let inner = &*self.inner;
        let b = inner.bottom.load(Ordering::Relaxed) - 1;
        let ring = inner.ring.load(Ordering::Relaxed);
        inner.bottom.store(b, Ordering::Relaxed);
        // Order the bottom decrement against the thieves' top reads.
        fence(Ordering::SeqCst);
        let t = inner.top.load(Ordering::Relaxed);
        if t <= b {
            // Non-empty: the speculative read is safe to *use* unless we
            // lose the last-element race below.
            let v = unsafe { (*ring).read(b) };
            if t == b {
                // Last element: race thieves for it via the top CAS.
                let won = inner
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                inner.bottom.store(b + 1, Ordering::Relaxed);
                if !won {
                    // A thief claimed it; our copy must not drop.
                    std::mem::forget(v);
                    return None;
                }
            }
            Some(v)
        } else {
            // Empty: restore bottom.
            inner.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Owner-side size estimate (exact on the owner thread).
    pub fn len(&self) -> usize {
        let b = self.inner.bottom.load(Ordering::Relaxed);
        let t = self.inner.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Whether the deque looks empty from the owner's side.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Double the ring, copying live logical indices; retires the old
    /// ring until the deque drops (in-flight thieves may still read it).
    unsafe fn grow(&self, old: *mut Ring<T>, b: isize, t: isize) -> *mut Ring<T> {
        let new = Ring::alloc((*old).cap() * 2);
        for i in t..b {
            // Bit-copy; the old slot's copy is dead from here on (it is
            // never read once `ring` points at the doubled ring, except
            // by a thief whose logical index both rings agree on).
            (*new).write(i, (*old).read(i));
        }
        self.inner.ring.store(new, Ordering::Release);
        self.inner.retired.lock().unwrap().push(old);
        new
    }
}

impl<T: Send> Stealer<T> {
    /// Steal at the top — FIFO relative to the owner's `push`.
    pub fn steal(&self) -> Steal<T> {
        let inner = &*self.inner;
        let t = inner.top.load(Ordering::Acquire);
        // Order the top read against the owner's bottom decrement.
        fence(Ordering::SeqCst);
        let b = inner.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        let ring = inner.ring.load(Ordering::Acquire);
        // Speculative read; only ours if the CAS claims index `t`.
        let v = unsafe { (*ring).read(t) };
        if inner.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok() {
            Steal::Success(v)
        } else {
            std::mem::forget(v);
            Steal::Retry
        }
    }

    /// Racy size estimate (for heuristics only).
    pub fn len(&self) -> usize {
        let t = self.inner.top.load(Ordering::Relaxed);
        let b = self.inner.bottom.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Whether the deque looks empty right now.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    #[test]
    fn owner_take_is_lifo() {
        let (w, _s) = deque::<usize>();
        for i in 0..5 {
            w.push(i);
        }
        assert_eq!(w.take(), Some(4));
        assert_eq!(w.take(), Some(3));
        w.push(9);
        assert_eq!(w.take(), Some(9));
        assert_eq!(w.take(), Some(2));
        assert_eq!(w.take(), Some(1));
        assert_eq!(w.take(), Some(0));
        assert_eq!(w.take(), None);
    }

    #[test]
    fn steal_is_fifo() {
        let (w, s) = deque::<usize>();
        for i in 0..4 {
            w.push(i);
        }
        match s.steal() {
            Steal::Success(v) => assert_eq!(v, 0),
            _ => panic!("steal from a quiet 4-element deque must succeed"),
        }
        match s.steal() {
            Steal::Success(v) => assert_eq!(v, 1),
            _ => panic!("second steal must succeed"),
        }
        // owner still sees the LIFO end
        assert_eq!(w.take(), Some(3));
        assert_eq!(w.take(), Some(2));
        assert_eq!(w.take(), None);
    }

    #[test]
    fn ring_grows_past_initial_capacity() {
        let (w, _s) = deque::<usize>();
        let n = INITIAL_CAP * 8 + 3;
        for i in 0..n {
            w.push(i);
        }
        assert_eq!(w.len(), n);
        let mut sum = 0usize;
        while let Some(v) = w.take() {
            sum += v;
        }
        assert_eq!(sum, n * (n - 1) / 2);
    }

    #[test]
    fn interleaved_push_take_across_growth() {
        // Push/takes straddling several growth boundaries keep LIFO order.
        let (w, _s) = deque::<usize>();
        let mut expect = Vec::new();
        for round in 0..10 {
            for i in 0..(INITIAL_CAP + 7) {
                w.push(round * 1000 + i);
                expect.push(round * 1000 + i);
            }
            for _ in 0..INITIAL_CAP / 2 {
                assert_eq!(w.take(), expect.pop());
            }
        }
        while let Some(v) = w.take() {
            assert_eq!(Some(v), expect.pop());
        }
        assert!(expect.is_empty());
    }

    #[test]
    fn drop_releases_queued_elements() {
        // Arc elements: dropping a non-empty deque must drop its queue.
        let marker = Arc::new(());
        {
            let (w, _s) = deque::<Arc<()>>();
            for _ in 0..(INITIAL_CAP * 3) {
                w.push(Arc::clone(&marker));
            }
            let _ = w.take(); // leave a mix of taken and queued
        }
        assert_eq!(Arc::strong_count(&marker), 1, "queued elements leaked on drop");
    }

    #[test]
    fn concurrent_steal_take_conserves_items() {
        // Owner pushes N and takes; 3 thieves steal; every item is
        // consumed exactly once (the take/steal last-element race).
        const N: usize = 20_000;
        let (w, s) = deque::<usize>();
        let seen: Arc<Vec<AtomicBool>> =
            Arc::new((0..N).map(|_| AtomicBool::new(false)).collect());
        let consumed = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicBool::new(false));
        let thieves: Vec<_> = (0..3)
            .map(|_| {
                let s = s.clone();
                let seen = Arc::clone(&seen);
                let consumed = Arc::clone(&consumed);
                let done = Arc::clone(&done);
                std::thread::spawn(move || loop {
                    match s.steal() {
                        Steal::Success(v) => {
                            assert!(!seen[v].swap(true, Ordering::SeqCst), "dup {v}");
                            consumed.fetch_add(1, Ordering::SeqCst);
                        }
                        Steal::Retry => std::hint::spin_loop(),
                        Steal::Empty => {
                            if done.load(Ordering::SeqCst) {
                                return;
                            }
                            std::hint::spin_loop();
                        }
                    }
                })
            })
            .collect();
        for v in 0..N {
            w.push(v);
            // interleave takes so both ends race for real
            if v % 3 == 0 {
                if let Some(got) = w.take() {
                    assert!(!seen[got].swap(true, Ordering::SeqCst), "dup {got}");
                    consumed.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        while let Some(got) = w.take() {
            assert!(!seen[got].swap(true, Ordering::SeqCst), "dup {got}");
            consumed.fetch_add(1, Ordering::SeqCst);
        }
        // drain stragglers the thieves raced us for
        while consumed.load(Ordering::SeqCst) < N {
            std::hint::spin_loop();
        }
        done.store(true, Ordering::SeqCst);
        for th in thieves {
            th.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::SeqCst), N);
        assert!(seen.iter().all(|b| b.load(Ordering::SeqCst)));
    }

    #[test]
    fn growth_under_concurrent_steals() {
        // Force repeated growth while thieves are active: starts at
        // INITIAL_CAP and pushes far beyond it without the owner taking.
        const N: usize = 50_000;
        let (w, s) = deque::<usize>();
        let stolen = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicBool::new(false));
        let thieves: Vec<_> = (0..2)
            .map(|_| {
                let s = s.clone();
                let stolen = Arc::clone(&stolen);
                let done = Arc::clone(&done);
                std::thread::spawn(move || {
                    let mut sum = 0usize;
                    loop {
                        match s.steal() {
                            Steal::Success(v) => {
                                sum += v;
                                stolen.fetch_add(1, Ordering::SeqCst);
                            }
                            Steal::Retry => {}
                            Steal::Empty => {
                                if done.load(Ordering::SeqCst) && s.is_empty() {
                                    return sum;
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let mut owner_sum = 0usize;
        for v in 0..N {
            w.push(v);
        }
        while let Some(v) = w.take() {
            owner_sum += v;
        }
        done.store(true, Ordering::SeqCst);
        let thief_sum: usize = thieves.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(owner_sum + thief_sum, N * (N - 1) / 2);
    }
}
