//! Folly-style pool: bounded lock-free MPMC ring buffer + LIFO wake-up.
//!
//! Two Folly CPUThreadPoolExecutor ideas reproduced here:
//!
//! * the queue is a fixed-capacity MPMC ring with per-slot sequence
//!   numbers (Vyukov's design, what folly::MPMCQueue implements —
//!   shared with the Eigen pool's injector as
//!   [`super::mpmc::MpmcQueue`]) — enqueue and dequeue are single-CAS
//!   operations with no shared lock;
//! * idle workers park on a LIFO stack ("LifoSem"), so the most recently
//!   active (cache-warm) worker wakes first, and the rest stay asleep
//!   instead of stampeding.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::mpmc::MpmcQueue;
use super::{Task, TaskPool};

const QUEUE_CAP: usize = 4096; // power of two

/// LIFO parking lot: most recently parked worker wakes first.
struct LifoSem {
    stack: Mutex<Vec<usize>>, // worker ids, top = most recent
    cvs: Box<[(Mutex<bool>, Condvar)]>,
}

impl LifoSem {
    fn new(n: usize) -> Self {
        LifoSem {
            stack: Mutex::new(Vec::with_capacity(n)),
            cvs: (0..n).map(|_| (Mutex::new(false), Condvar::new())).collect(),
        }
    }

    /// Park worker `id` until signalled (or timeout, for shutdown polling).
    fn park(&self, id: usize) {
        self.stack.lock().unwrap().push(id);
        let (lock, cv) = &self.cvs[id];
        let mut signalled = lock.lock().unwrap();
        if !*signalled {
            let (g, _t) = cv
                .wait_timeout(signalled, std::time::Duration::from_millis(2))
                .unwrap();
            signalled = g;
        }
        *signalled = false;
        // remove self if still on the stack (timeout path)
        let mut st = self.stack.lock().unwrap();
        if let Some(i) = st.iter().rposition(|&w| w == id) {
            st.remove(i);
        }
    }

    /// Wake the most recently parked worker, if any.
    fn post(&self) {
        let popped = self.stack.lock().unwrap().pop();
        if let Some(id) = popped {
            let (lock, cv) = &self.cvs[id];
            *lock.lock().unwrap() = true;
            cv.notify_one();
        }
    }
}

struct Shared {
    queue: MpmcQueue<Task>,
    sem: LifoSem,
    shutdown: AtomicBool,
    /// overflow list when the ring is full (rare)
    overflow: Mutex<Vec<Task>>,
}

/// The Folly-style pool.
pub struct FollyPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl FollyPool {
    /// Spawn `n` workers.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            queue: MpmcQueue::new(QUEUE_CAP),
            sem: LifoSem::new(n),
            shutdown: AtomicBool::new(false),
            overflow: Mutex::new(Vec::new()),
        });
        let workers = (0..n)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("folly-pool-{i}"))
                    .spawn(move || worker(s, i))
                    .expect("spawn")
            })
            .collect();
        FollyPool { shared, workers }
    }
}

fn take(shared: &Shared) -> Option<Task> {
    if let Some(t) = shared.queue.pop() {
        return Some(t);
    }
    let mut ov = shared.overflow.lock().unwrap();
    ov.pop()
}

fn worker(shared: Arc<Shared>, id: usize) {
    loop {
        // brief spin for latency
        let mut got = None;
        for _ in 0..32 {
            if let Some(t) = take(&shared) {
                got = Some(t);
                break;
            }
            std::hint::spin_loop();
        }
        if let Some(t) = got {
            t();
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            // drain fully before exiting
            while let Some(t) = take(&shared) {
                t();
            }
            return;
        }
        shared.sem.park(id);
    }
}

impl TaskPool for FollyPool {
    fn execute(&self, task: Task) {
        match self.shared.queue.push(task) {
            Ok(()) => {}
            Err(task) => self.shared.overflow.lock().unwrap().push(task),
        }
        self.shared.sem.post();
    }

    fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for FollyPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // wake everyone (parked workers poll shutdown on 2 ms timeout too)
        for _ in 0..self.workers.len() {
            self.shared.sem.post();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn overflow_path_executes() {
        // capacity is 4096; push 5000 no-ops through a 2-thread pool
        let pool = FollyPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = super::super::WaitGroup::new(5000);
        for _ in 0..5000 {
            let c = Arc::clone(&counter);
            let h = wg.handle();
            pool.execute(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
                h.done();
            }));
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 5000);
    }
}
