//! Thread pools — the paper's §6.2 designs, implemented for real.
//!
//! | pool        | queue                         | wake policy          |
//! |-------------|-------------------------------|----------------------|
//! | `StdPool`   | one mutex-guarded deque       | condvar broadcast    |
//! | `EigenPool` | per-thread deques + stealing  | spin-then-park       |
//! | `FollyPool` | bounded MPMC ring (atomics)   | LIFO parking stack   |
//!
//! All three run the same [`TaskPool`] interface so the coordinator and the
//! Fig. 14 benchmark can swap them via [`crate::config::PoolLib`].

mod eigen_pool;
mod folly_pool;
mod std_pool;

pub use eigen_pool::EigenPool;
pub use folly_pool::FollyPool;
pub use std_pool::StdPool;

use std::sync::{Arc, Condvar, Mutex};

use crate::config::PoolLib;

/// A boxed unit of work.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// Common interface over the three pool designs.
pub trait TaskPool: Send + Sync {
    /// Submit a task for asynchronous execution.
    fn execute(&self, task: Task);
    /// Number of worker threads.
    fn threads(&self) -> usize;
}

/// Construct a pool of `n` workers for the given library flavour.
pub fn make_pool(lib: PoolLib, n: usize) -> Arc<dyn TaskPool> {
    match lib {
        PoolLib::StdThread => Arc::new(StdPool::new(n)),
        PoolLib::Eigen => Arc::new(EigenPool::new(n)),
        PoolLib::Folly => Arc::new(FollyPool::new(n)),
    }
}

/// Counting latch used to join on a batch of submitted tasks.
pub struct WaitGroup {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl WaitGroup {
    /// New latch expecting `count` completions.
    pub fn new(count: usize) -> Self {
        WaitGroup { inner: Arc::new((Mutex::new(count), Condvar::new())) }
    }

    /// Signal one completion (call from the task).
    pub fn done(&self) {
        let (lock, cv) = &*self.inner;
        let mut n = lock.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            cv.notify_all();
        }
    }

    /// Cheap clone handle for moving into tasks.
    pub fn handle(&self) -> WaitGroup {
        WaitGroup { inner: Arc::clone(&self.inner) }
    }

    /// Block until all completions arrive.
    pub fn wait(&self) {
        let (lock, cv) = &*self.inner;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

/// Run `tasks` on `pool` and wait for all of them (the scatter/gather the
/// framework's intra-op parallelism uses).
pub fn scatter_gather(pool: &dyn TaskPool, tasks: Vec<Task>) {
    let wg = WaitGroup::new(tasks.len());
    for t in tasks {
        let h = wg.handle();
        pool.execute(Box::new(move || {
            t();
            h.done();
        }));
    }
    wg.wait();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn exercise(pool: Arc<dyn TaskPool>) {
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..1000)
            .map(|_| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        scatter_gather(pool.as_ref(), tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn all_pools_run_all_tasks() {
        for lib in PoolLib::ALL {
            exercise(make_pool(lib, 4));
        }
    }

    #[test]
    fn single_thread_pools_work() {
        for lib in PoolLib::ALL {
            exercise(make_pool(lib, 1));
        }
    }

    #[test]
    fn oversubscribed_pools_work() {
        // 64 threads on this tiny machine — the Fig. 14 stress shape
        for lib in PoolLib::ALL {
            let pool = make_pool(lib, 64);
            assert_eq!(pool.threads(), 64);
            exercise(pool);
        }
    }

    #[test]
    fn waitgroup_zero_is_immediate() {
        WaitGroup::new(0).wait();
    }

    #[test]
    fn tasks_can_submit_tasks() {
        let pool = make_pool(PoolLib::Folly, 2);
        let wg = WaitGroup::new(1);
        let h = wg.handle();
        let p2 = Arc::clone(&pool);
        pool.execute(Box::new(move || {
            p2.execute(Box::new(move || h.done()));
        }));
        wg.wait();
    }
}
