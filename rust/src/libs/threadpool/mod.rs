//! Thread pools — the paper's §6.2 designs, implemented for real.
//!
//! | pool            | queue                                   | wake policy          |
//! |-----------------|-----------------------------------------|----------------------|
//! | `StdPool`       | one mutex-guarded deque                 | condvar broadcast    |
//! | `EigenPool`     | per-worker Chase–Lev deques + lock-free injector | eventcount (wake only if parked) |
//! | `FollyPool`     | bounded MPMC ring (atomics)             | LIFO parking stack   |
//! | `ReferencePool` | per-thread mutexed deques (PR 4–8 pool) | global idle mutex + condvar |
//!
//! All four run the same [`TaskPool`] interface so the coordinator, the
//! tuner's sweep executor, and the Fig. 14 benchmark can swap them.
//! `EigenPool` is the production substrate (see `chase_lev`,
//! `eventcount`); `ReferencePool` is its preserved mutex-based
//! predecessor, kept as the measured baseline for
//! `BENCH_threadpool.json`'s `fastpath-vs-reference` cases.

mod chase_lev;
mod eigen_pool;
mod eventcount;
mod folly_pool;
mod mpmc;
mod reference_pool;
mod std_pool;

pub use eigen_pool::EigenPool;
pub use folly_pool::FollyPool;
pub use reference_pool::ReferencePool;
pub use std_pool::StdPool;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::PoolLib;

/// A boxed unit of work.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// Common interface over the pool designs.
pub trait TaskPool: Send + Sync {
    /// Submit a task for asynchronous execution.
    fn execute(&self, task: Task);

    /// Submit a batch of tasks with (at most) one wake decision,
    /// proportional to the batch size. The default just loops
    /// [`TaskPool::execute`]; `EigenPool` overrides it with a real
    /// batched injection.
    fn execute_batch(&self, tasks: Vec<Task>) {
        for t in tasks {
            self.execute(t);
        }
    }

    /// Submit a batch whose completions are counted on `wg` by the
    /// pool itself. `EigenPool` carries the latch inside its queue
    /// units — no wrapper closure, no second box per task; the default
    /// wraps (which is exactly the per-task overhead the reference
    /// plane is measured with).
    fn execute_batch_counted(&self, tasks: Vec<Task>, wg: &WaitGroup) {
        for t in tasks {
            let h = wg.handle();
            self.execute(Box::new(move || {
                t();
                h.done();
            }));
        }
    }

    /// Number of worker threads.
    fn threads(&self) -> usize;
}

/// Construct a pool of `n` workers for the given library flavour.
pub fn make_pool(lib: PoolLib, n: usize) -> Arc<dyn TaskPool> {
    match lib {
        PoolLib::StdThread => Arc::new(StdPool::new(n)),
        PoolLib::Eigen => Arc::new(EigenPool::new(n)),
        PoolLib::Folly => Arc::new(FollyPool::new(n)),
    }
}

struct WgInner {
    count: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

/// Counting latch used to join on a batch of submitted tasks.
///
/// `done` is lock-free except for the *final* decrement: the count is
/// an atomic, and only the completion that drops it to zero touches
/// the mutex/condvar pair to release waiters (the old implementation
/// took a Mutex+Condvar round-trip on every single completion).
pub struct WaitGroup {
    inner: Arc<WgInner>,
}

impl WaitGroup {
    /// New latch expecting `count` completions.
    pub fn new(count: usize) -> Self {
        WaitGroup {
            inner: Arc::new(WgInner {
                count: AtomicUsize::new(count),
                lock: Mutex::new(()),
                cv: Condvar::new(),
            }),
        }
    }

    /// Signal one completion (call from the task). Only the last
    /// completion takes the lock, to hand off to waiters.
    pub fn done(&self) {
        if self.inner.count.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Taking the lock before notifying pins any waiter either
            // before its count check (it will see 0) or inside
            // `cv.wait` (the notify reaches it) — no lost wakeup.
            let _guard = self.inner.lock.lock().unwrap();
            self.inner.cv.notify_all();
        }
    }

    /// Cheap clone handle for moving into tasks.
    pub fn handle(&self) -> WaitGroup {
        WaitGroup { inner: Arc::clone(&self.inner) }
    }

    /// Block until all completions arrive.
    pub fn wait(&self) {
        if self.inner.count.load(Ordering::Acquire) == 0 {
            return;
        }
        let mut guard = self.inner.lock.lock().unwrap();
        while self.inner.count.load(Ordering::Acquire) > 0 {
            guard = self.inner.cv.wait(guard).unwrap();
        }
    }

    /// Completions still outstanding (racy; tests only).
    pub fn outstanding(&self) -> usize {
        self.inner.count.load(Ordering::Acquire)
    }
}

/// Run `tasks` on `pool` and wait for all of them (the scatter/gather
/// the framework's intra-op parallelism uses). Rides the pool's batch
/// path: one submission, one wake decision, completions counted inside
/// the pool where it supports it.
pub fn scatter_gather(pool: &dyn TaskPool, tasks: Vec<Task>) {
    let wg = WaitGroup::new(tasks.len());
    pool.execute_batch_counted(tasks, &wg);
    wg.wait();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn exercise(pool: Arc<dyn TaskPool>) {
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..1000)
            .map(|_| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        scatter_gather(pool.as_ref(), tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn all_pools_run_all_tasks() {
        for lib in PoolLib::ALL {
            exercise(make_pool(lib, 4));
        }
        exercise(Arc::new(ReferencePool::new(4)));
    }

    #[test]
    fn single_thread_pools_work() {
        for lib in PoolLib::ALL {
            exercise(make_pool(lib, 1));
        }
        exercise(Arc::new(ReferencePool::new(1)));
    }

    #[test]
    fn oversubscribed_pools_work() {
        // 64 threads on this tiny machine — the Fig. 14 stress shape
        for lib in PoolLib::ALL {
            let pool = make_pool(lib, 64);
            assert_eq!(pool.threads(), 64);
            exercise(pool);
        }
        let reference = Arc::new(ReferencePool::new(64));
        assert_eq!(reference.threads(), 64);
        exercise(reference);
    }

    #[test]
    fn waitgroup_zero_is_immediate() {
        WaitGroup::new(0).wait();
    }

    #[test]
    fn waitgroup_counts_down_once_per_done() {
        let wg = WaitGroup::new(3);
        assert_eq!(wg.outstanding(), 3);
        wg.done();
        wg.done();
        assert_eq!(wg.outstanding(), 1);
        let h = wg.handle();
        let waiter = std::thread::spawn(move || h.wait());
        wg.done();
        waiter.join().unwrap();
        assert_eq!(wg.outstanding(), 0);
    }

    #[test]
    fn waitgroup_releases_many_waiters() {
        let wg = WaitGroup::new(1);
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                let h = wg.handle();
                std::thread::spawn(move || h.wait())
            })
            .collect();
        wg.done();
        for w in waiters {
            w.join().unwrap();
        }
    }

    #[test]
    fn tasks_can_submit_tasks() {
        let pool = make_pool(PoolLib::Folly, 2);
        let wg = WaitGroup::new(1);
        let h = wg.handle();
        let p2 = Arc::clone(&pool);
        pool.execute(Box::new(move || {
            p2.execute(Box::new(move || h.done()));
        }));
        wg.wait();
    }

    #[test]
    fn execute_batch_default_matches_loop() {
        // the default trait impl must behave like per-task execute on
        // every pool flavour
        for lib in PoolLib::ALL {
            let pool = make_pool(lib, 2);
            let counter = Arc::new(AtomicUsize::new(0));
            let wg = WaitGroup::new(100);
            let tasks: Vec<Task> = (0..100)
                .map(|_| {
                    let c = Arc::clone(&counter);
                    let h = wg.handle();
                    Box::new(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                        h.done();
                    }) as Task
                })
                .collect();
            pool.execute_batch(tasks);
            wg.wait();
            assert_eq!(counter.load(Ordering::Relaxed), 100, "{lib:?}");
        }
    }
}
