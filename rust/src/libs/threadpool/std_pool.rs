//! Naive `std::thread` pool: one mutex-guarded queue, condvar broadcast.
//!
//! This is the paper's baseline design — every push and pop serialises on
//! the same lock, and every `notify_all` stampedes all sleepers. Fine at
//! 4 threads, collapses at 64 (Fig. 14's 3× overhead growth, ~60% of each
//! core spent in synchronisation).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::{Task, TaskPool};

struct Shared {
    queue: Mutex<State>,
    cv: Condvar,
}

struct State {
    tasks: VecDeque<Task>,
    shutdown: bool,
}

/// The naive pool.
pub struct StdPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl StdPool {
    /// Spawn `n` workers.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { tasks: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("std-pool-{i}"))
                    .spawn(move || worker(s))
                    .expect("spawn")
            })
            .collect();
        StdPool { shared, workers }
    }
}

fn worker(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = st.tasks.pop_front() {
                    break t;
                }
                if st.shutdown {
                    return;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        task();
    }
}

impl TaskPool for StdPool {
    fn execute(&self, task: Task) {
        let mut st = self.shared.queue.lock().unwrap();
        st.tasks.push_back(task);
        drop(st);
        // broadcast wake-up: the design flaw the paper measures
        self.shared.cv.notify_all();
    }

    fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for StdPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn drains_queue_on_shutdown() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = StdPool::new(2);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }));
            }
            // Drop joins workers only after the queue empties…
            while counter.load(Ordering::Relaxed) < 100 {
                std::thread::yield_now();
            }
        }
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }
}
