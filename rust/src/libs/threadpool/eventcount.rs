//! Eventcount: the parking layer under the lock-free pool.
//!
//! An eventcount is the condvar of lock-free land (Eigen's
//! `EventCount`, folly's `LifoSem` underpinnings): it lets a worker
//! park on "nothing in any queue" without any lock on the submit path,
//! and without lost wakeups. The protocol is a two-phase wait against
//! an epoch counter plus one park slot per worker:
//!
//! * **worker** — [`EventCount::prepare`]: mark own slot `WAITING`,
//!   register in the waiter count, read the epoch. Then *re-check the
//!   queues*. Work found → [`EventCount::cancel`]; still empty →
//!   [`EventCount::commit`], which blocks unless the epoch moved or a
//!   notifier already picked this slot.
//! * **submitter** — after publishing work, [`EventCount::notify`]:
//!   one `SeqCst` read of the waiter count; zero (the common case on a
//!   busy pool) means *done* — no fence, no lock, no syscall. Nonzero
//!   means bump the epoch and wake the requested number of `WAITING`
//!   slots through their tiny per-slot mutexes.
//!
//! Why no lost wakeup: `prepare` orders `WAITING`-store → waiter-count
//! increment → epoch read, all `SeqCst`; `notify` orders work-publish →
//! waiter-count read. If the notifier reads waiters == 0, the worker's
//! increment is later in the total order, so its epoch read (later
//! still) synchronizes with any prior epoch bump and — decisively —
//! its queue re-check sees the published work and cancels. If the
//! notifier reads waiters > 0, the registered slot is already
//! `WAITING` and the scan wakes it.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

const EMPTY: usize = 0;
const WAITING: usize = 1;
const NOTIFIED: usize = 2;

/// Belt-and-braces park bound. The eventcount protocol makes wakeups
/// lock-free-correct on its own; the timeout only bounds the damage of
/// a hypothetical platform/ordering bug to 100 ms instead of a hang,
/// and keeps a persistent idle pool near 0% CPU (10 self-wakes/s).
const PARK_TIMEOUT: Duration = Duration::from_millis(100);

struct ParkSlot {
    state: AtomicUsize,
    /// `true` = a wake is pending for this slot.
    signal: Mutex<bool>,
    cv: Condvar,
}

/// The eventcount: one epoch, one waiter count, one slot per worker.
pub struct EventCount {
    epoch: AtomicU64,
    nwaiters: AtomicUsize,
    slots: Box<[ParkSlot]>,
}

impl EventCount {
    /// Eventcount for `n` workers (slot index = worker index).
    pub fn new(n: usize) -> Self {
        EventCount {
            epoch: AtomicU64::new(0),
            nwaiters: AtomicUsize::new(0),
            slots: (0..n)
                .map(|_| ParkSlot {
                    state: AtomicUsize::new(EMPTY),
                    signal: Mutex::new(false),
                    cv: Condvar::new(),
                })
                .collect(),
        }
    }

    /// Phase one of parking: register worker `me` as a waiter and
    /// return the epoch key for [`Self::commit`]. The caller MUST
    /// re-check its queues between `prepare` and `commit`/`cancel`.
    pub fn prepare(&self, me: usize) -> u64 {
        self.slots[me].state.store(WAITING, Ordering::SeqCst);
        self.nwaiters.fetch_add(1, Ordering::SeqCst);
        self.epoch.load(Ordering::SeqCst)
    }

    /// Abort a prepared wait (the re-check found work). If a notifier
    /// had already picked this slot, the wake is passed on to another
    /// parked worker so the notification is never swallowed.
    pub fn cancel(&self, me: usize) {
        self.nwaiters.fetch_sub(1, Ordering::SeqCst);
        let slot = &self.slots[me];
        let prev = slot.state.swap(EMPTY, Ordering::SeqCst);
        if prev == NOTIFIED {
            *slot.signal.lock().unwrap() = false;
            self.notify(1);
        }
    }

    /// Phase two: block until notified, the epoch moves past `key`, or
    /// the belt-and-braces timeout fires. Always deregisters.
    pub fn commit(&self, me: usize, key: u64) {
        let slot = &self.slots[me];
        {
            let mut signal = slot.signal.lock().unwrap();
            while !*signal {
                if self.epoch.load(Ordering::SeqCst) != key {
                    break;
                }
                let (guard, timeout) = slot.cv.wait_timeout(signal, PARK_TIMEOUT).unwrap();
                signal = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            *signal = false;
        }
        self.nwaiters.fetch_sub(1, Ordering::SeqCst);
        // A NOTIFIED state here is *our* notification — consumed by the
        // rescan the caller is about to run.
        slot.state.store(EMPTY, Ordering::SeqCst);
    }

    /// Wake up to `n` parked workers. The no-waiter fast path is a
    /// single `SeqCst` load — this is what makes uncontended submission
    /// "a push plus one atomic read".
    pub fn notify(&self, n: usize) {
        if self.nwaiters.load(Ordering::SeqCst) == 0 {
            return;
        }
        self.epoch.fetch_add(1, Ordering::SeqCst);
        let mut woken = 0;
        for slot in self.slots.iter() {
            if woken >= n {
                break;
            }
            if slot
                .state
                .compare_exchange(WAITING, NOTIFIED, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                let mut signal = slot.signal.lock().unwrap();
                *signal = true;
                slot.cv.notify_one();
                woken += 1;
            }
        }
    }

    /// Wake every parked worker unconditionally (shutdown). Bumps the
    /// epoch even with no registered waiter so a worker racing through
    /// `prepare` sees the world changed and re-checks.
    pub fn notify_all(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        for slot in self.slots.iter() {
            if slot
                .state
                .compare_exchange(WAITING, NOTIFIED, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                let mut signal = slot.signal.lock().unwrap();
                *signal = true;
                slot.cv.notify_one();
            }
        }
    }

    /// Registered waiters right now (racy; tests and heuristics only).
    pub fn waiters(&self) -> usize {
        self.nwaiters.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn notify_without_waiters_is_a_noop() {
        let ec = EventCount::new(2);
        let e0 = ec.epoch.load(Ordering::SeqCst);
        ec.notify(1);
        // fast path: epoch untouched, nothing to wake
        assert_eq!(ec.epoch.load(Ordering::SeqCst), e0);
        assert_eq!(ec.waiters(), 0);
    }

    #[test]
    fn cancel_clears_registration() {
        let ec = EventCount::new(1);
        let _key = ec.prepare(0);
        assert_eq!(ec.waiters(), 1);
        ec.cancel(0);
        assert_eq!(ec.waiters(), 0);
    }

    #[test]
    fn epoch_move_between_prepare_and_commit_does_not_sleep() {
        let ec = EventCount::new(1);
        let key = ec.prepare(0);
        // a notify between prepare and commit bumps the epoch…
        ec.notify_all();
        let t0 = std::time::Instant::now();
        ec.commit(0, key); // …so commit returns without the full timeout
        assert!(t0.elapsed() < PARK_TIMEOUT, "commit slept through a moved epoch");
        assert_eq!(ec.waiters(), 0);
    }

    #[test]
    fn parked_worker_wakes_on_notify() {
        let ec = Arc::new(EventCount::new(1));
        let parked = Arc::new(AtomicBool::new(false));
        let woke = Arc::new(AtomicBool::new(false));
        let (ec2, parked2, woke2) = (Arc::clone(&ec), Arc::clone(&parked), Arc::clone(&woke));
        let th = std::thread::spawn(move || {
            let key = ec2.prepare(0);
            parked2.store(true, Ordering::SeqCst);
            ec2.commit(0, key);
            woke2.store(true, Ordering::SeqCst);
        });
        while !parked.load(Ordering::SeqCst) {
            std::hint::spin_loop();
        }
        ec.notify(1);
        th.join().unwrap();
        assert!(woke.load(Ordering::SeqCst));
    }

    #[test]
    fn notify_all_wakes_every_parked_worker() {
        let n = 4;
        let ec = Arc::new(EventCount::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let ec = Arc::clone(&ec);
                std::thread::spawn(move || {
                    let key = ec.prepare(i);
                    ec.commit(i, key);
                })
            })
            .collect();
        // let them all reach the park (racy but bounded by PARK_TIMEOUT
        // — a worker that parks after the notify self-wakes anyway)
        std::thread::sleep(Duration::from_millis(10));
        ec.notify_all();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ec.waiters(), 0);
    }
}
