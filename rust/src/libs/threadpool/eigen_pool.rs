//! Eigen-style pool, rebuilt as a lock-free substrate.
//!
//! PR 4–8 dogfooded this pool under every hot sweep, but its deques
//! were `Mutex<VecDeque>` and every `execute` took a *global* idle
//! mutex — the faster the sim/search fast paths got, the larger the
//! share of each sweep spent serialising on pool locks. This rebuild
//! removes the locks from every steady-state path:
//!
//! * each worker owns a [`chase_lev`] stealing deque — owner pushes
//!   and takes LIFO at the bottom with plain atomics, thieves steal
//!   FIFO at the top with one CAS;
//! * external submissions go through a lock-free Vyukov MPMC
//!   *injector* ring ([`mpmc::MpmcQueue`]), falling back to a mutexed
//!   overflow list only under extreme burst;
//! * a task spawned *from inside a worker* lands in that worker's own
//!   deque via a thread-local registry — no shared cursor, no lock,
//!   and the spawning worker's next `take` gets it cache-warm;
//! * parking is an [`eventcount::EventCount`] — uncontended submission
//!   is a queue push plus one `SeqCst` read of the waiter count, and a
//!   wake happens only when a worker is actually parked;
//! * [`EigenPool::execute_batch`] / `execute_batch_counted` inject a
//!   whole chunk of tasks with a single pending update and one wake
//!   decision proportional to the batch size, and count completions on
//!   the [`WaitGroup`] *inside* the pool — no wrapper closure, no
//!   second box per task.
//!
//! The previous mutex-based implementation is preserved verbatim as
//! [`super::ReferencePool`] — the measured baseline for
//! `BENCH_threadpool.json`'s `fastpath-vs-reference` cases.
//!
//! Shutdown drains: `Drop` wakes everyone and workers only exit once
//! the pool is both shut down and observably empty (`pending == 0`),
//! so no submitted task is dropped.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::prng::Prng;

use super::chase_lev::{self, Steal};
use super::eventcount::EventCount;
use super::mpmc::MpmcQueue;
use super::{Task, TaskPool, WaitGroup};

/// Injector ring capacity; bursts beyond it spill to the overflow list.
const INJECTOR_CAP: usize = 8192;

/// Scan attempts before a worker gives up and parks.
const SPIN_TRIES: usize = 64;

/// One queued unit of work: the task plus the batch latch the pool
/// itself decrements on completion (the no-double-box path that
/// `scatter_gather` rides).
struct Unit {
    task: Task,
    wg: Option<WaitGroup>,
}

impl Unit {
    fn run(self) {
        (self.task)();
        if let Some(wg) = self.wg {
            wg.done();
        }
    }
}

/// Process-unique pool ids for the thread-local worker registry
/// (id 0 = "not a pool worker").
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (owning pool id, pointer to this thread's own deque). Set once
    /// when a worker thread starts; the pointer targets the `worker`
    /// stack frame, which outlives every task the worker runs.
    static CURRENT_WORKER: Cell<(u64, *const ())> = const { Cell::new((0, std::ptr::null())) };
}

struct Shared {
    pool_id: u64,
    stealers: Vec<chase_lev::Stealer<Unit>>,
    injector: MpmcQueue<Unit>,
    /// Burst spill-over when the injector ring is full (rare).
    overflow: Mutex<VecDeque<Unit>>,
    overflow_len: AtomicUsize,
    ec: EventCount,
    shutdown: AtomicBool,
    /// Submitted-but-not-yet-popped units: workers drain to zero before
    /// exiting at shutdown, and skip parking while it is nonzero.
    pending: AtomicUsize,
    // --- observability (tests + tuning) ---
    local_submits: AtomicUsize,
    injected: AtomicUsize,
    steals: AtomicUsize,
}

/// The lock-free work-stealing pool.
pub struct EigenPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl EigenPool {
    /// Spawn `n` workers, each owning a Chase–Lev deque.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let mut owners = Vec::with_capacity(n);
        let mut stealers = Vec::with_capacity(n);
        for _ in 0..n {
            let (w, s) = chase_lev::deque::<Unit>();
            owners.push(w);
            stealers.push(s);
        }
        let shared = Arc::new(Shared {
            pool_id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            stealers,
            injector: MpmcQueue::new(INJECTOR_CAP),
            overflow: Mutex::new(VecDeque::new()),
            overflow_len: AtomicUsize::new(0),
            ec: EventCount::new(n),
            shutdown: AtomicBool::new(false),
            pending: AtomicUsize::new(0),
            local_submits: AtomicUsize::new(0),
            injected: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
        });
        let workers = owners
            .into_iter()
            .enumerate()
            .map(|(i, own)| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("eigen-pool-{i}"))
                    .spawn(move || worker(s, i, own))
                    .expect("spawn")
            })
            .collect();
        EigenPool { shared, workers }
    }

    /// Tasks that took the worker-local fast path (submitted from
    /// inside a worker of this pool, straight into its own deque).
    pub fn local_submits(&self) -> usize {
        self.shared.local_submits.load(Ordering::Relaxed)
    }

    /// Tasks that went through the external-submission injector.
    pub fn injected(&self) -> usize {
        self.shared.injected.load(Ordering::Relaxed)
    }

    /// Successful cross-worker steals so far.
    pub fn steals(&self) -> usize {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// This pool's own deque for the calling thread, when the calling
    /// thread is one of this pool's workers.
    fn local_worker(&self) -> Option<&chase_lev::Worker<Unit>> {
        let (id, ptr) = CURRENT_WORKER.with(|c| c.get());
        if id == self.shared.pool_id && !ptr.is_null() {
            // In-bounds by construction: the registry entry was written
            // by this very thread when its worker loop started, and the
            // deque it points at lives in that loop's frame below us on
            // this same thread's stack.
            Some(unsafe { &*(ptr as *const chase_lev::Worker<Unit>) })
        } else {
            None
        }
    }

    fn submit(&self, unit: Unit) {
        // pending rises before the unit is reachable, so shutdown can
        // never observe "empty" while a push is in flight.
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        if let Some(local) = self.local_worker() {
            local.push(unit);
            self.shared.local_submits.fetch_add(1, Ordering::Relaxed);
        } else {
            inject(&self.shared, unit);
            self.shared.injected.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.ec.notify(1);
    }

    fn submit_batch(&self, tasks: Vec<Task>, wg: Option<&WaitGroup>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        self.shared.pending.fetch_add(n, Ordering::SeqCst);
        if let Some(local) = self.local_worker() {
            for task in tasks {
                local.push(Unit { task, wg: wg.map(|w| w.handle()) });
            }
            self.shared.local_submits.fetch_add(n, Ordering::Relaxed);
        } else {
            for task in tasks {
                inject(&self.shared, Unit { task, wg: wg.map(|w| w.handle()) });
            }
            self.shared.injected.fetch_add(n, Ordering::Relaxed);
        }
        // one wake decision for the whole batch, sized to it
        self.shared.ec.notify(n.min(self.shared.stealers.len()));
    }
}

fn inject(shared: &Shared, unit: Unit) {
    match shared.injector.push(unit) {
        Ok(()) => {}
        Err(unit) => {
            let mut ov = shared.overflow.lock().unwrap();
            ov.push_back(unit);
            shared.overflow_len.fetch_add(1, Ordering::Release);
        }
    }
}

fn pop_injected(shared: &Shared) -> Option<Unit> {
    // Drain the (older) overflow first so a burst can't starve it.
    if shared.overflow_len.load(Ordering::Acquire) > 0 {
        let mut ov = shared.overflow.lock().unwrap();
        if let Some(u) = ov.pop_front() {
            shared.overflow_len.fetch_sub(1, Ordering::Release);
            return Some(u);
        }
    }
    shared.injector.pop()
}

fn find_work(
    shared: &Shared,
    local: &chase_lev::Worker<Unit>,
    me: usize,
    rng: &mut Prng,
) -> Option<Unit> {
    // own deque first (LIFO end — cache-warm)…
    if let Some(u) = local.take() {
        return Some(u);
    }
    // …then external submissions…
    if let Some(u) = pop_injected(shared) {
        return Some(u);
    }
    // …then steal a victim's FIFO end, random start for fairness.
    let n = shared.stealers.len();
    if n > 1 {
        let start = rng.below(n);
        for _pass in 0..2 {
            let mut contended = false;
            for off in 0..n {
                let v = (start + off) % n;
                if v == me {
                    continue;
                }
                match shared.stealers[v].steal() {
                    Steal::Success(u) => {
                        shared.steals.fetch_add(1, Ordering::Relaxed);
                        return Some(u);
                    }
                    Steal::Retry => contended = true,
                    Steal::Empty => {}
                }
            }
            if !contended {
                break;
            }
        }
    }
    None
}

fn worker(shared: Arc<Shared>, me: usize, local: chase_lev::Worker<Unit>) {
    CURRENT_WORKER
        .with(|c| c.set((shared.pool_id, &local as *const chase_lev::Worker<Unit> as *const ())));
    let mut rng = Prng::new(me as u64 ^ 0x5eed);
    loop {
        // spin-scan phase
        let mut unit = None;
        for _ in 0..SPIN_TRIES {
            if shared.pending.load(Ordering::Acquire) > 0 {
                if let Some(u) = find_work(&shared, &local, me, &mut rng) {
                    unit = Some(u);
                    break;
                }
            }
            std::hint::spin_loop();
        }
        if let Some(u) = unit {
            shared.pending.fetch_sub(1, Ordering::AcqRel);
            u.run();
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire) && shared.pending.load(Ordering::Acquire) == 0
        {
            break;
        }
        // park phase: two-phase eventcount wait with a queue re-check
        // in the middle (see eventcount.rs for the no-lost-wake proof)
        let key = shared.ec.prepare(me);
        if shared.pending.load(Ordering::SeqCst) > 0 || shared.shutdown.load(Ordering::SeqCst) {
            shared.ec.cancel(me);
            continue;
        }
        shared.ec.commit(me, key);
    }
    CURRENT_WORKER.with(|c| c.set((0, std::ptr::null())));
}

impl TaskPool for EigenPool {
    fn execute(&self, task: Task) {
        self.submit(Unit { task, wg: None });
    }

    fn execute_batch(&self, tasks: Vec<Task>) {
        self.submit_batch(tasks, None);
    }

    fn execute_batch_counted(&self, tasks: Vec<Task>, wg: &WaitGroup) {
        self.submit_batch(tasks, Some(wg));
    }

    fn threads(&self) -> usize {
        self.shared.stealers.len()
    }
}

impl Drop for EigenPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.ec.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steals_across_deques() {
        // A burst submitted from outside lands in the injector; workers
        // race it down and balance by stealing when one worker hoards.
        let pool = EigenPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(64);
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            let h = wg.handle();
            pool.execute(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
                h.done();
            }));
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
        assert_eq!(pool.injected(), 64, "external submissions go through the injector");
    }

    #[test]
    fn worker_local_submission_skips_the_injector() {
        let pool = Arc::new(EigenPool::new(2));
        let wg = WaitGroup::new(1 + 32);
        let h = wg.handle();
        let p2 = Arc::clone(&pool);
        pool.execute(Box::new(move || {
            // from worker context: children take the local fast path
            for _ in 0..32 {
                let h2 = h.handle();
                p2.execute(Box::new(move || h2.done()));
            }
            h.done();
        }));
        wg.wait();
        assert_eq!(pool.local_submits(), 32, "worker-spawned tasks must land locally");
        assert_eq!(pool.injected(), 1, "only the seed task came from outside");
    }

    #[test]
    fn batch_counted_runs_everything_without_wrappers() {
        let pool = EigenPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..500)
            .map(|_| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        let wg = WaitGroup::new(tasks.len());
        pool.execute_batch_counted(tasks, &wg);
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn injector_overflow_spills_and_drains() {
        // more external tasks than the injector ring holds
        let pool = EigenPool::new(2);
        let n = INJECTOR_CAP + 2000;
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..n)
            .map(|_| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        let wg = WaitGroup::new(n);
        pool.execute_batch_counted(tasks, &wg);
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), n);
    }

    #[test]
    fn shutdown_drains_pending_work() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = EigenPool::new(2);
            for _ in 0..2000 {
                let c = Arc::clone(&counter);
                pool.execute(Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }));
            }
            // drop immediately: the pool must drain, not discard
        }
        assert_eq!(counter.load(Ordering::Relaxed), 2000);
    }
}
