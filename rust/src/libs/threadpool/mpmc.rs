//! Vyukov bounded MPMC ring buffer, generic over the element.
//!
//! Per-slot sequence numbers make enqueue and dequeue single-CAS
//! operations with no shared lock — this is what `folly::MPMCQueue`
//! implements, and both the Folly-style pool's run queue and the
//! Eigen-style pool's external-submission injector are instances of it.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

struct Slot<T> {
    seq: AtomicUsize,
    value: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free MPMC queue (capacity must be a power of two).
pub struct MpmcQueue<T> {
    slots: Box<[Slot<T>]>,
    head: AtomicUsize, // dequeue cursor
    tail: AtomicUsize, // enqueue cursor
    mask: usize,
}

unsafe impl<T: Send> Send for MpmcQueue<T> {}
unsafe impl<T: Send> Sync for MpmcQueue<T> {}

impl<T> MpmcQueue<T> {
    /// New queue with `cap` slots.
    pub fn new(cap: usize) -> Self {
        assert!(cap.is_power_of_two());
        let slots = (0..cap)
            .map(|i| Slot { seq: AtomicUsize::new(i), value: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        MpmcQueue { slots, head: AtomicUsize::new(0), tail: AtomicUsize::new(0), mask: cap - 1 }
    }

    /// Try to enqueue; returns the value back when full.
    pub fn push(&self, value: T) -> Result<(), T> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.value.get()).write(value) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return Err(value); // full
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Try to dequeue.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let value = unsafe { (*slot.value.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(value);
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return None; // empty
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }
}

impl<T> Drop for MpmcQueue<T> {
    fn drop(&mut self) {
        // Sole handle at drop: release whatever is still queued.
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn fifo_single_thread() {
        let q = MpmcQueue::new(8);
        for i in 0..5 {
            assert!(q.push(i).is_ok());
        }
        let mut out = Vec::new();
        while let Some(v) = q.pop() {
            out.push(v);
        }
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn full_reports_back() {
        let q = MpmcQueue::new(2);
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.pop(), Some(1));
        assert!(q.push(3).is_ok());
    }

    #[test]
    fn boxed_closures_run_in_order() {
        let q: MpmcQueue<Box<dyn FnOnce() + Send>> = MpmcQueue::new(8);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..5 {
            let l = Arc::clone(&log);
            assert!(q.push(Box::new(move || l.lock().unwrap().push(i))).is_ok());
        }
        while let Some(t) = q.pop() {
            t();
        }
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn drop_releases_queued_elements() {
        let marker = Arc::new(());
        {
            let q = MpmcQueue::new(8);
            for _ in 0..6 {
                assert!(q.push(Arc::clone(&marker)).is_ok());
            }
            let _ = q.pop();
        }
        assert_eq!(Arc::strong_count(&marker), 1);
    }

    #[test]
    fn concurrent_producers_consumers_conserve() {
        use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
        let q = Arc::new(MpmcQueue::new(64));
        let produced = 4 * 5_000usize;
        let consumed = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicBool::new(false));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                let consumed = Arc::clone(&consumed);
                let sum = Arc::clone(&sum);
                let done = Arc::clone(&done);
                std::thread::spawn(move || loop {
                    match q.pop() {
                        Some(v) => {
                            sum.fetch_add(v, Ordering::Relaxed);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        None => {
                            if done.load(Ordering::SeqCst)
                                && consumed.load(Ordering::SeqCst) >= produced
                            {
                                return;
                            }
                            std::hint::spin_loop();
                        }
                    }
                })
            })
            .collect();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..5_000usize {
                        let mut v = p * 5_000 + i;
                        loop {
                            match q.push(v) {
                                Ok(()) => break,
                                Err(back) => {
                                    v = back;
                                    std::hint::spin_loop();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        done.store(true, Ordering::SeqCst);
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::SeqCst), produced);
        assert_eq!(sum.load(Ordering::SeqCst), produced * (produced - 1) / 2);
    }
}
