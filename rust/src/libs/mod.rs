//! Library back ends (paper §6).
//!
//! * [`math`] — analytic models of the MKL / MKL-DNN / Eigen GEMM kernels:
//!   efficiency vs size, prefetch effectiveness, LLC behaviour, top-down
//!   cycle breakdown (the Fig. 13 quantities). These feed the simulator.
//! * [`threadpool`] — three *real, runnable* thread pools mirroring the
//!   designs the paper benchmarks in Fig. 14: a naive `std::thread` pool, an
//!   Eigen-style work-stealing pool, and a Folly-style MPMC pool with LIFO
//!   wake-up. They execute the coordinator's work and are measured by
//!   `benches/threadpool.rs`.

pub mod math;
pub mod threadpool;

pub use math::MathModel;
pub use threadpool::{make_pool, TaskPool, WaitGroup};
