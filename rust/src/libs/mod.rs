//! Library back ends (paper §6).
//!
//! * [`math`] — analytic models of the MKL / MKL-DNN / Eigen GEMM kernels:
//!   efficiency vs size, prefetch effectiveness, LLC behaviour, top-down
//!   cycle breakdown (the Fig. 13 quantities). These feed the simulator.
//! * [`threadpool`] — *real, runnable* thread pools mirroring the designs
//!   the paper benchmarks in Fig. 14: a naive `std::thread` pool, the
//!   lock-free Eigen-style work-stealing pool (Chase–Lev deques +
//!   eventcount parking), a Folly-style MPMC pool with LIFO wake-up, and
//!   the preserved mutex-based `ReferencePool` baseline. They execute the
//!   coordinator's work and are measured by `benches/threadpool.rs`.

pub mod math;
pub mod threadpool;

pub use math::MathModel;
pub use threadpool::{make_pool, TaskPool, WaitGroup};
