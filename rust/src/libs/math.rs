//! Analytic models of the math-library GEMM kernels (paper §6.1, Fig. 13).
//!
//! The paper's top-down analysis found, for single-threaded GEMM:
//!
//! * **MKL** — highest retiring ratio and IPC; LLC MPKI stays low even for
//!   out-of-cache matrices because its software prefetching is *effective*
//!   (nearly all memory traffic is prefetch, not demand misses).
//! * **MKL-DNN** — close second on FLOPs; ~25% back-end-bound beyond 4k,
//!   MPKI an order of magnitude above MKL.
//! * **Eigen** — lowest efficiency and IPC; prefetching least aggressive.
//!
//! These curves are calibrated to reproduce Fig. 13's *relations* (who wins
//! and by roughly how much), not the authors' absolute counter values; the
//! simulator consumes [`MathModel::gemm_efficiency`] and
//! [`MathModel::parallel_efficiency`] to turn op FLOPs into time.

use crate::config::{CpuPlatform, MathLib};

/// Top-down cycle breakdown (fractions sum to 1.0) + IPC.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TopDown {
    /// Useful work retired.
    pub retiring: f64,
    /// Front-end (fetch/decode) stalls.
    pub frontend: f64,
    /// Bad speculation.
    pub bad_speculation: f64,
    /// Back-end core-bound (port contention).
    pub backend_core: f64,
    /// Back-end memory-bound (cache/DRAM stalls).
    pub backend_memory: f64,
    /// Instructions per cycle.
    pub ipc: f64,
}

/// Memory-traffic split for one GEMM (GB moved).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemTraffic {
    /// Bytes brought in by software/hardware prefetch (hidden latency).
    pub prefetch_gb: f64,
    /// Bytes brought in by demand LLC misses (exposed latency).
    pub demand_gb: f64,
}

/// Per-library analytic model.
#[derive(Debug, Clone, Copy)]
pub struct MathModel {
    /// Which library this models.
    pub lib: MathLib,
}

impl MathModel {
    /// Model for a library.
    pub fn new(lib: MathLib) -> Self {
        MathModel { lib }
    }

    /// Peak-fraction a single-threaded square-`n` GEMM achieves.
    ///
    /// Shape: rises with `n` (amortising loop prologue + packing), saturates
    /// at a per-library ceiling. Small kernels (the SqueezeNet 1×1 regime)
    /// sit well under half of peak.
    pub fn gemm_efficiency(&self, n: f64) -> f64 {
        let (ceil, half_n) = match self.lib {
            MathLib::Mkl => (0.92, 180.0),
            MathLib::MklDnn => (0.86, 220.0),
            MathLib::Eigen => (0.72, 300.0),
        };
        // saturating rise: eff = ceil * n / (n + half_n)
        let base = ceil * n / (n + half_n);
        // out-of-LLC penalty: Eigen/MKL-DNN lose ~15–25% beyond ~4k because
        // of demand misses; MKL's prefetching holds its efficiency
        let oversize = (n / 4096.0).min(2.0).max(0.0);
        let penalty = match self.lib {
            MathLib::Mkl => 1.0 - 0.02 * (oversize - 1.0).max(0.0),
            MathLib::MklDnn => 1.0 - 0.10 * (oversize - 1.0).max(0.0),
            MathLib::Eigen => 1.0 - 0.12 * (oversize - 1.0).max(0.0),
        };
        base * penalty
    }

    /// Efficiency for a general (possibly non-square) GEMM: use the
    /// geometric-mean dimension as the effective size.
    pub fn gemm_efficiency_mkn(&self, m: f64, k: f64, n: f64) -> f64 {
        self.gemm_efficiency((m * k * n).powf(1.0 / 3.0))
    }

    /// Thread-scaling efficiency: fraction of linear speedup that `t`
    /// kernel threads achieve on compute (before the serial prep terms the
    /// simulator adds). Saturating: `s(t) = t / (1 + (t-1)/T)`, calibrated
    /// so a large GEMM peaks near the paper's measured ~16× at 24 MKL
    /// threads (Fig. 9) rather than scaling linearly.
    pub fn parallel_efficiency(&self, threads: usize) -> f64 {
        self.saturating_eff(threads, match self.lib {
            MathLib::Mkl => 40.0,
            MathLib::MklDnn => 36.0,
            MathLib::Eigen => 28.0,
        })
    }

    /// Thread scaling for im2col convolutions: the staged matrix's
    /// irregular access pattern saturates much earlier than a packed GEMM
    /// (this is why the paper's inception workloads prefer 3 pools × 8
    /// threads over one 24-thread pool, Fig. 4).
    pub fn parallel_efficiency_conv(&self, threads: usize) -> f64 {
        self.saturating_eff(threads, match self.lib {
            MathLib::Mkl => 12.0,
            MathLib::MklDnn => 12.0,
            MathLib::Eigen => 9.0,
        })
    }

    fn saturating_eff(&self, threads: usize, sat: f64) -> f64 {
        if threads <= 1 {
            return 1.0;
        }
        let t = threads as f64;
        // speedup s(t) = t / (1 + (t-1)/sat); efficiency = s(t)/t
        1.0 / (1.0 + (t - 1.0) / sat)
    }

    /// LLC misses per kilo-instruction for a square-`n` single-thread GEMM
    /// on a platform with the given LLC (Fig. 13b).
    pub fn llc_mpki(&self, n: f64, platform: &CpuPlatform) -> f64 {
        // working set of the blocked panel ≈ 3 · n² · 4 B; compare to LLC
        let ws_mib = 3.0 * n * n * 4.0 / (1024.0 * 1024.0);
        let pressure = (ws_mib / platform.llc_mib_per_socket).min(4.0);
        let (base, slope) = match self.lib {
            MathLib::Mkl => (0.05, 0.4), // prefetch hides almost everything
            MathLib::MklDnn => (0.15, 1.6),
            MathLib::Eigen => (0.25, 2.0),
        };
        if pressure <= 1.0 {
            base * pressure
        } else {
            base + slope * (pressure - 1.0).min(2.0)
        }
    }

    /// Memory-traffic split (Fig. 13c): total traffic is similar across
    /// libraries; MKL moves nearly all of it via prefetch.
    pub fn mem_traffic(&self, n: f64, platform: &CpuPlatform) -> MemTraffic {
        // total bytes ≈ reuse-blocked GEMM traffic: 3·n²·4 · (n/block)
        let block = 256.0;
        let total_gb = 3.0 * n * n * 4.0 * (n / block).max(1.0) / 1e9;
        let mpki = self.llc_mpki(n, platform);
        let max_mpki = 4.3; // Eigen deep out-of-cache
        let demand_frac = (mpki / max_mpki).min(1.0)
            * match self.lib {
                MathLib::Mkl => 0.08,
                MathLib::MklDnn => 0.55,
                MathLib::Eigen => 0.75,
            };
        MemTraffic { prefetch_gb: total_gb * (1.0 - demand_frac), demand_gb: total_gb * demand_frac }
    }

    /// Top-down cycle breakdown + IPC (Fig. 13a).
    pub fn topdown(&self, n: f64, platform: &CpuPlatform) -> TopDown {
        let mpki = self.llc_mpki(n, platform);
        // memory-bound cycles grow with MPKI; saturate at 45%
        let backend_memory = (mpki * 0.085).min(0.45);
        let (frontend, bad_speculation, backend_core) = match self.lib {
            MathLib::Mkl => (0.03, 0.01, 0.06),
            MathLib::MklDnn => (0.05, 0.02, 0.08),
            MathLib::Eigen => (0.08, 0.03, 0.12),
        };
        let retiring = (1.0 - frontend - bad_speculation - backend_core - backend_memory).max(0.1);
        // Skylake retires up to 4 µops/cycle; GEMM's FMA mix caps ~3.5
        let ipc = 3.5 * retiring + 0.3;
        TopDown { retiring, frontend, bad_speculation, backend_core, backend_memory, ipc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CpuPlatform {
        CpuPlatform::small()
    }

    #[test]
    fn mkl_wins_gemm_at_all_sizes() {
        for n in [64.0, 256.0, 1024.0, 4096.0, 16384.0] {
            let mkl = MathModel::new(MathLib::Mkl).gemm_efficiency(n);
            let dnn = MathModel::new(MathLib::MklDnn).gemm_efficiency(n);
            let eig = MathModel::new(MathLib::Eigen).gemm_efficiency(n);
            assert!(mkl > dnn && dnn > eig, "n={n}: {mkl} {dnn} {eig}");
        }
    }

    #[test]
    fn efficiency_rises_with_size() {
        let m = MathModel::new(MathLib::Mkl);
        assert!(m.gemm_efficiency(64.0) < m.gemm_efficiency(512.0));
        assert!(m.gemm_efficiency(512.0) < m.gemm_efficiency(4096.0));
    }

    #[test]
    fn optimization_gap_is_about_25_percent() {
        // paper §6: "optimization can improve a GEMM kernel's performance
        // by up to 25%" (MKL over the others)
        let n = 8192.0;
        let mkl = MathModel::new(MathLib::Mkl).gemm_efficiency(n);
        let eig = MathModel::new(MathLib::Eigen).gemm_efficiency(n);
        let gain = mkl / eig - 1.0;
        assert!(gain > 0.2 && gain < 0.6, "gain={gain}");
    }

    #[test]
    fn mkl_mpki_order_of_magnitude_lower() {
        let p = small();
        let n = 8192.0; // far out of 8 MiB LLC
        let mkl = MathModel::new(MathLib::Mkl).llc_mpki(n, &p);
        let dnn = MathModel::new(MathLib::MklDnn).llc_mpki(n, &p);
        let eig = MathModel::new(MathLib::Eigen).llc_mpki(n, &p);
        assert!(dnn / mkl > 3.0, "mkl={mkl} dnn={dnn}");
        assert!(eig > dnn, "eigen={eig} dnn={dnn}");
    }

    #[test]
    fn backend_bound_25pct_beyond_4k_for_eigen_dnn() {
        let p = small();
        for lib in [MathLib::Eigen, MathLib::MklDnn] {
            let td = MathModel::new(lib).topdown(8192.0, &p);
            let backend = td.backend_memory + td.backend_core;
            assert!(backend > 0.2 && backend < 0.6, "{lib:?}: {backend}");
        }
        let mkl = MathModel::new(MathLib::Mkl).topdown(8192.0, &p);
        assert!(mkl.backend_memory < 0.1, "{:?}", mkl);
    }

    #[test]
    fn mkl_highest_ipc() {
        let p = small();
        let ipc = |l| MathModel::new(l).topdown(4096.0, &p).ipc;
        assert!(ipc(MathLib::Mkl) > ipc(MathLib::MklDnn));
        assert!(ipc(MathLib::MklDnn) > ipc(MathLib::Eigen));
    }

    #[test]
    fn mkl_traffic_mostly_prefetch() {
        let p = small();
        let t = MathModel::new(MathLib::Mkl).mem_traffic(8192.0, &p);
        assert!(t.prefetch_gb / (t.prefetch_gb + t.demand_gb) > 0.9);
        let e = MathModel::new(MathLib::Eigen).mem_traffic(8192.0, &p);
        assert!(e.demand_gb / (e.prefetch_gb + e.demand_gb) > 0.3);
    }

    #[test]
    fn breakdown_sums_to_one() {
        let p = small();
        for lib in MathLib::ALL {
            for n in [128.0, 1024.0, 8192.0] {
                let td = MathModel::new(lib).topdown(n, &p);
                let sum = td.retiring + td.frontend + td.bad_speculation
                    + td.backend_core + td.backend_memory;
                assert!((sum - 1.0).abs() < 1e-9, "{lib:?} n={n}: {sum}");
            }
        }
    }

    #[test]
    fn parallel_efficiency_monotone_decreasing() {
        let m = MathModel::new(MathLib::Mkl);
        assert_eq!(m.parallel_efficiency(1), 1.0);
        assert!(m.parallel_efficiency(24) < m.parallel_efficiency(4));
        // Fig. 9 anchor: ~16× max speedup at 24 threads
        let s24 = 24.0 * m.parallel_efficiency(24);
        assert!(s24 > 13.0 && s24 < 18.0, "s24={s24}");
    }
}
