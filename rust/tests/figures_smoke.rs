//! Integration: every figure/table generator produces non-empty output
//! with its expected headline rows (the CLI `figures --all` path).

use parframe::bench_tables;

#[test]
fn every_figure_renders() {
    for n in bench_tables::FIGURES {
        let s = bench_tables::figure(n).unwrap_or_else(|| panic!("fig {n}"));
        assert!(s.len() > 80, "fig {n} too short:\n{s}");
        assert!(s.contains(&format!("Fig {n}")), "fig {n} missing header");
    }
}

#[test]
fn table2_renders() {
    let s = bench_tables::table(2).unwrap();
    assert!(s.contains("Table 2"));
    assert!(s.contains("transformer"));
}

#[test]
fn unknown_numbers_are_none() {
    assert!(bench_tables::figure(2).is_none());
    assert!(bench_tables::figure(99).is_none());
    assert!(bench_tables::table(1).is_none());
}

#[test]
fn table3_policy_comparison_renders() {
    let s = bench_tables::table(3).unwrap();
    assert!(s.contains("Table 3"));
    assert!(s.contains("critical-path"));
    assert!(s.contains("transformer") && s.contains("resnet50"));
}

#[test]
fn fig9_rows_cover_sweep() {
    let s = bench_tables::figure(9).unwrap();
    for size in ["256", "512", "4096", "16384"] {
        assert!(s.contains(size), "fig9 missing size {size}");
    }
}

#[test]
fn fig18_reports_geomeans() {
    let s = bench_tables::figure(18).unwrap();
    assert!(s.contains("geomean"));
    assert!(s.contains("optimum"));
    for model in bench_tables::evaluation::EVAL_MODELS {
        assert!(s.contains(model), "fig18 missing {model}");
    }
}

#[test]
fn fig13_lists_all_libraries() {
    let s = bench_tables::figure(13).unwrap();
    for lib in ["MKL-DNN", "Eigen"] {
        assert!(s.contains(lib));
    }
}

#[test]
fn fig14_has_model_and_measurement() {
    let s = bench_tables::figure(14).unwrap();
    assert!(s.contains("modelled"));
    assert!(s.contains("measured"));
    assert!(s.contains("Folly"));
}
