//! Integration: the tuner's guideline vs baselines vs exhaustive search
//! across platforms (the paper's §8 evaluation, beyond the large.2 runs
//! already asserted in the unit tests).

use parframe::config::CpuPlatform;
use parframe::models;
use parframe::sim;
use parframe::tuner::{baseline_config, exhaustive_search, tune, Baseline};

#[test]
fn guideline_matches_search_on_single_socket_too() {
    // the paper's ≥95% claim is for large.2 (asserted in the unit tests);
    // on a single socket we allow slightly more slack — fewer cores make
    // the pools-vs-threads lattice coarser (24/4 = 6-thread pools)
    let p = CpuPlatform::large();
    for name in ["resnet50", "ncf", "wide_deep"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let guided = sim::simulate(&g, &p, &tune(&g, &p).config).unwrap().latency_s;
        let opt = exhaustive_search(&g, &p).unwrap().best_latency_s;
        assert!(guided / opt < 1.08, "{name}: {:.3}", guided / opt);
    }
}

#[test]
fn guideline_scales_threads_with_platform() {
    let g = models::build("wide_deep", 16).unwrap();
    let small = tune(&g, &CpuPlatform::small()).config;
    let large = tune(&g, &CpuPlatform::large2()).config;
    assert_eq!(small.inter_op_pools, 3);
    assert_eq!(large.inter_op_pools, 3);
    assert_eq!(small.mkl_threads, 1); // 4 cores / 3 pools
    assert_eq!(large.mkl_threads, 16); // 48 cores / 3 pools
}

#[test]
fn design_space_is_collapsed_to_one_point() {
    // the paper: one prediction out of 96³ possibilities on large.2
    let p = CpuPlatform::large2();
    let raw_space = p.logical_cores() * p.logical_cores() * p.logical_cores();
    assert_eq!(raw_space, 884_736);
    let g = models::build("ncf", 256).unwrap();
    let searched = exhaustive_search(&g, &p).unwrap().evaluated;
    // the pruned lattice is large but the guideline evaluates 0 of it
    assert!(searched > 100, "searched={searched}");
    let t1 = tune(&g, &p).config;
    let t2 = tune(&g, &p).config;
    assert_eq!(t1, t2);
}

#[test]
fn tf_default_worst_across_models() {
    let p = CpuPlatform::large2();
    for name in ["resnet50", "transformer", "ncf"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let dflt = sim::simulate(&g, &p, &baseline_config(Baseline::TensorFlowDefault, &p))
            .unwrap()
            .latency_s;
        let rec = sim::simulate(&g, &p, &baseline_config(Baseline::TensorFlowRecommended, &p))
            .unwrap()
            .latency_s;
        let guided = sim::simulate(&g, &p, &tune(&g, &p).config).unwrap().latency_s;
        assert!(dflt > rec, "{name}: default should lose to recommended");
        assert!(dflt > guided * 2.0, "{name}: default should lose badly");
    }
}

#[test]
fn guideline_beats_intel_and_tensorflow_across_zoo() {
    // The paper's headline claim (§8 / Fig. 18): width-guided settings
    // beat the Intel and TensorFlow recommendations — 1.29×/1.34× on the
    // authors' hardware. Assert the conservative smoke bound (mean
    // simulated latency strictly better, speedup > 1.0) across the whole
    // model zoo on large.2, and report the measured ratios.
    let p = CpuPlatform::large2();
    let mut ours = Vec::new();
    let mut intel = Vec::new();
    let mut tf = Vec::new();
    for name in models::model_names() {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let guided = sim::simulate(&g, &p, &tune(&g, &p).config).unwrap().latency_s;
        let i = sim::simulate(&g, &p, &baseline_config(Baseline::IntelRecommended, &p))
            .unwrap()
            .latency_s;
        let t = sim::simulate(&g, &p, &baseline_config(Baseline::TensorFlowRecommended, &p))
            .unwrap()
            .latency_s;
        assert!(guided.is_finite() && guided > 0.0, "{name}");
        ours.push(guided);
        intel.push(i);
        tf.push(t);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let speedup_intel = mean(&intel) / mean(&ours);
    let speedup_tf = mean(&tf) / mean(&ours);
    println!("zoo mean speedup vs Intel-recommended: {speedup_intel:.2}x");
    println!("zoo mean speedup vs TensorFlow-recommended: {speedup_tf:.2}x");
    assert!(speedup_intel > 1.0, "guideline must beat Intel: {speedup_intel:.3}x");
    assert!(speedup_tf > 1.0, "guideline must beat TensorFlow: {speedup_tf:.3}x");
}

#[test]
fn guideline_beats_baselines_on_sim_backend_latencies() {
    // the same claim observed through the serving stack's SimBackend:
    // tuner-chosen knobs (the default) yield lower simulated batch
    // latency than pinned baseline knobs, per (kind, bucket)
    use parframe::runtime::{SimBackend, SimBackendConfig};
    let p = CpuPlatform::large2();
    let kinds = ["resnet50", "wide_deep", "ncf"];
    let tuned = SimBackend::new(SimBackendConfig::new(p.clone(), &kinds)).unwrap();
    for b in [Baseline::IntelRecommended, Baseline::TensorFlowRecommended] {
        let mut cfg = SimBackendConfig::new(p.clone(), &kinds);
        cfg.framework = Some(baseline_config(b, &p));
        let base = SimBackend::new(cfg).unwrap();
        let mut wins = 0usize;
        let mut total = 0usize;
        for kind in kinds {
            for bucket in [1usize, 2, 4, 8] {
                let t = tuned.simulated_latency(kind, bucket).unwrap();
                let s = base.simulated_latency(kind, bucket).unwrap();
                total += 1;
                if t <= s {
                    wins += 1;
                }
            }
        }
        // tuned wins the aggregate comfortably even if an odd point ties
        assert!(wins * 2 > total, "{:?}: tuned won {wins}/{total}", b.name());
    }
}

#[test]
fn guideline_on_training_graphs_is_sane() {
    let p = CpuPlatform::large2();
    for name in ["resnet50", "fc4k"] {
        let fwd = models::build(name, models::canonical_batch(name)).unwrap();
        let train = models::to_training_graph(&fwd);
        let t = tune(&train, &p);
        assert!(t.config.validate(&p).is_ok(), "{name}");
        assert!(!t.config.over_threaded(&p), "{name}");
        let guided = sim::simulate(&train, &p, &t.config).unwrap().latency_s;
        let rec = sim::simulate(
            &train,
            &p,
            &baseline_config(Baseline::TensorFlowRecommended, &p),
        )
        .unwrap()
        .latency_s;
        assert!(guided <= rec * 1.05, "{name}: guided={guided} rec={rec}");
    }
}
