//! Integration: width analysis across the whole model zoo (paper Table 2
//! + Fig. 4's max-width column), and batch-robustness of the guideline.

use parframe::graph::analyze_width;
use parframe::models;

#[test]
fn table2_widths_exact() {
    let expect = [
        ("densenet121", 1),
        ("squeezenet", 1),
        ("resnet50", 1),
        ("inception_v3", 2),
        ("wide_deep", 3),
        ("ncf", 4),
        ("transformer", 4),
    ];
    for (name, want) in expect {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        assert_eq!(analyze_width(&g).avg_width, want, "{name}");
    }
}

#[test]
fn max_widths_match_architectures() {
    // four-branch inception modules; two-path residual blocks; parallel
    // embedding tables
    let expect_max = [
        ("googlenet", 4),
        ("inception_v2", 4),
        ("resnet50", 2),
        ("squeezenet", 2),
        ("densenet121", 1),
        ("caffenet", 1),
        ("ncf", 4),
        ("wide_deep", 3),
    ];
    for (name, want) in expect_max {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        assert_eq!(analyze_width(&g).max_width, want, "{name}");
    }
}

#[test]
fn widths_stable_across_batch_sizes() {
    // the guideline must not flap with batch size for the vision set.
    // (NCF/W&D are excluded: at larger batches their MLP towers cross the
    // heavy threshold, genuinely changing the parallel structure —
    // the paper likewise notes best pool counts shift with batch, §4.1.)
    for name in ["resnet50", "inception_v3", "squeezenet", "densenet121"] {
        let w16 = analyze_width(&models::build(name, models::canonical_batch(name)).unwrap());
        let w2x = analyze_width(
            &models::build(name, models::canonical_batch(name) * 2).unwrap(),
        );
        assert_eq!(w16.avg_width, w2x.avg_width, "{name}");
    }
}

#[test]
fn training_graphs_widen() {
    for name in ["resnet50", "caffenet", "fc4k"] {
        let fwd = models::build(name, models::canonical_batch(name)).unwrap();
        let train = models::to_training_graph(&fwd);
        let wf = analyze_width(&fwd);
        let wt = analyze_width(&train);
        assert!(wt.max_width >= wf.max_width.max(2), "{name}: {wt:?}");
        assert!(wt.heavy_ops > 2 * wf.heavy_ops, "{name}");
    }
}

#[test]
fn every_zoo_graph_is_valid_dag() {
    for name in models::model_names() {
        for batch in [1, models::canonical_batch(name)] {
            let g = models::build(name, batch).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{name}@{batch}: {e}"));
        }
    }
}
