//! Property-based tests (in-tree harness: seeded PRNG over many random
//! cases — crates.io proptest is unavailable offline).
//!
//! Invariants covered:
//! * simulator: monotonicity, determinism, conservation of work;
//! * scheduling policies: every policy runs each node exactly once and
//!   never before its deps; all policies agree on pure chain graphs;
//! * batcher: order preservation, bucket sufficiency, no request loss;
//! * width analysis: bounds and invariance;
//! * JSON codec: roundtrip on random documents;
//! * loadgen: same seed ⇒ same open-loop schedule and closed-loop order;
//! * least-loaded dispatch: always a minimum-load host, never starves.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use parframe::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use parframe::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use parframe::coordinator::loadgen;
use parframe::coordinator::request::{Request, RequestId};
use parframe::graph::{analyze_width, Graph, GraphBuilder};
use parframe::ops::OpKind;
use parframe::runtime::{KindId, Tensor};
use parframe::sched::{pick_lane, ReadyQueue};
use parframe::sim;
use parframe::util::json::{self, Json};
use parframe::util::prng::Prng;

const CASES: usize = 40;

/// Random layered DAG with heavy/light ops.
fn random_graph(rng: &mut Prng) -> Graph {
    let mut b = GraphBuilder::new("random", 16);
    let layers = rng.range(2, 6);
    let mut prev_layer: Vec<parframe::graph::NodeId> = Vec::new();
    let root = b.add("in", OpKind::DataMovement { bytes: 1024, name: "Feed" }, &[]);
    prev_layer.push(root);
    for l in 0..layers {
        let width = rng.range(1, 5);
        let mut layer = Vec::new();
        for w in 0..width {
            let n_deps = rng.range(1, prev_layer.len());
            let mut deps = prev_layer.clone();
            rng.shuffle(&mut deps);
            deps.truncate(n_deps);
            let kind = if rng.f64() < 0.7 {
                let m = rng.range(64, 1024);
                OpKind::MatMul { m, k: rng.range(64, 1024), n: rng.range(64, 1024) }
            } else {
                OpKind::Elementwise { elems: rng.range(100, 100_000), name: "ReLU" }
            };
            layer.push(b.add(&format!("l{l}w{w}"), kind, &deps));
        }
        prev_layer = layer;
    }
    b.build()
}

fn random_cfg(rng: &mut Prng, p: &CpuPlatform) -> FrameworkConfig {
    FrameworkConfig {
        inter_op_pools: rng.range(1, p.physical_cores().min(8)),
        mkl_threads: rng.range(1, p.physical_cores()),
        intra_op_threads: rng.range(1, p.physical_cores()),
        operator_impl: if rng.f64() < 0.5 { OperatorImpl::Serial } else { OperatorImpl::IntraOpParallel },
        sched_policy: *rng.choose(&SchedPolicy::ALL),
        ..FrameworkConfig::tuned_default()
    }
}

#[test]
fn prop_simulation_deterministic_and_finite() {
    let mut rng = Prng::new(0xC0FFEE);
    let p = CpuPlatform::large();
    for case in 0..CASES {
        let g = random_graph(&mut rng);
        let cfg = random_cfg(&mut rng, &p);
        let a = sim::simulate(&g, &p, &cfg).unwrap();
        let b = sim::simulate(&g, &p, &cfg).unwrap();
        assert_eq!(a.latency_s, b.latency_s, "case {case}");
        assert!(a.latency_s.is_finite() && a.latency_s > 0.0, "case {case}");
        assert!(a.breakdown.total().is_finite(), "case {case}");
    }
}

#[test]
fn prop_tuned_big_platform_never_loses_to_tuned_small() {
    // tuner-level monotonicity: a tuned `large` run beats a tuned `small`
    // run (raw per-core speed differs — small clocks higher — but the
    // tuned large config has 6× the cores to deploy)
    let mut rng = Prng::new(0xBEEF);
    for case in 0..CASES {
        let g = random_graph(&mut rng);
        let small_p = CpuPlatform::small();
        let large_p = CpuPlatform::large();
        let small = sim::simulate(&g, &small_p, &parframe::tuner::tune(&g, &small_p).config)
            .unwrap()
            .latency_s;
        let large = sim::simulate(&g, &large_p, &parframe::tuner::tune(&g, &large_p).config)
            .unwrap()
            .latency_s;
        assert!(large <= small * 1.05, "case {case}: small={small} large={large}");
    }
}

#[test]
fn prop_every_policy_runs_each_node_once_after_its_deps() {
    // drive the ReadyQueue like an async pool set: pop a few ready nodes
    // into flight, complete them in random order, repeat — under every
    // policy each node must run exactly once and only after its deps
    let mut rng = Prng::new(0x5C11ED);
    for case in 0..CASES {
        let g = random_graph(&mut rng);
        for policy in SchedPolicy::ALL {
            let mut q = ReadyQueue::with_policy(&g, policy);
            let mut done = vec![false; g.len()];
            let mut in_flight: Vec<usize> = Vec::new();
            let mut executed = 0usize;
            while !q.finished() {
                let slots = rng.range(1, 4);
                while in_flight.len() < slots {
                    match q.pop() {
                        Some(n) => {
                            assert!(!done[n], "case {case} {policy:?}: node {n} ran twice");
                            for d in &g.nodes[n].deps {
                                assert!(
                                    done[d.0],
                                    "case {case} {policy:?}: node {n} before dep {}",
                                    d.0
                                );
                            }
                            in_flight.push(n);
                        }
                        None => break,
                    }
                }
                assert!(!in_flight.is_empty(), "case {case} {policy:?}: deadlock");
                let n = in_flight.swap_remove(rng.below(in_flight.len()));
                done[n] = true;
                executed += 1;
                q.complete(n);
            }
            assert_eq!(executed, g.len(), "case {case} {policy:?}: node count");
            assert_eq!(q.pop(), None, "case {case} {policy:?}: queue not drained");
        }
    }
}

#[test]
fn prop_all_policies_agree_on_pure_chains() {
    // a chain has no reordering freedom: every policy must produce the
    // bit-identical schedule, hence bit-identical latency
    let mut rng = Prng::new(0xC4A19);
    let p = CpuPlatform::large();
    for case in 0..CASES {
        let mut b = GraphBuilder::new("chain", 8);
        let mut prev = b.add("n0", OpKind::MatMul { m: rng.range(64, 512), k: 256, n: 256 }, &[]);
        let len = rng.range(3, 12);
        for i in 1..len {
            let kind = if rng.f64() < 0.6 {
                OpKind::MatMul { m: rng.range(64, 512), k: 256, n: 256 }
            } else {
                OpKind::Elementwise { elems: rng.range(1_000, 100_000), name: "ReLU" }
            };
            prev = b.add(&format!("n{i}"), kind, &[prev]);
        }
        b.add("out", OpKind::Pool { elems: 256 }, &[prev]);
        let g = b.build();
        let cfg = random_cfg(&mut rng, &p);
        let topo = sim::simulate(
            &g,
            &p,
            &FrameworkConfig { sched_policy: SchedPolicy::Topo, ..cfg.clone() },
        )
        .unwrap()
        .latency_s;
        for policy in [SchedPolicy::CriticalPathFirst, SchedPolicy::CostlyFirst] {
            let lat = sim::simulate(
                &g,
                &p,
                &FrameworkConfig { sched_policy: policy, ..cfg.clone() },
            )
            .unwrap()
            .latency_s;
            assert_eq!(lat, topo, "case {case} {policy:?}: chains must not reorder");
        }
    }
}

#[test]
fn prop_width_bounds() {
    let mut rng = Prng::new(0xF00D);
    for case in 0..CASES {
        let g = random_graph(&mut rng);
        let w = analyze_width(&g);
        let heavy = g.heavy_nodes().count();
        assert_eq!(w.heavy_ops, heavy, "case {case}");
        assert!(w.max_width <= heavy.max(1), "case {case}");
        assert!(w.avg_width >= 1, "case {case}");
        assert!(w.avg_width <= w.max_width.max(1), "case {case}");
        assert_eq!(w.per_level.iter().sum::<usize>(), heavy, "case {case}");
    }
}

#[test]
fn prop_tuned_config_always_valid() {
    let mut rng = Prng::new(0xDADA);
    for case in 0..CASES {
        let g = random_graph(&mut rng);
        for p in [CpuPlatform::small(), CpuPlatform::large(), CpuPlatform::large2()] {
            let t = parframe::tuner::tune(&g, &p);
            assert!(t.config.validate(&p).is_ok(), "case {case} on {}", p.name);
            assert!(!t.config.over_threaded(&p), "case {case} on {}", p.name);
        }
    }
}

fn mk_req(id: u64) -> Request {
    let (tx, _rx) = std::sync::mpsc::channel();
    Request {
        id: RequestId(id),
        kind: KindId(0),
        input: Tensor { shape: vec![1, 4], data: vec![0.0; 4] },
        enqueued: Instant::now(),
        reply: tx,
    }
}

/// Like [`mk_req`] but with a caller-chosen enqueue timestamp (virtual
/// arrival times for the dispatch-deadline property).
fn mk_req_at(id: u64, enqueued: Instant) -> Request {
    let mut r = mk_req(id);
    r.enqueued = enqueued;
    r
}

/// Random bucket ladder: 1..=4 distinct sizes in [1, 16].
fn random_buckets(rng: &mut Prng) -> Vec<usize> {
    let n = rng.range(1, 4);
    let mut v: Vec<usize> = (0..n).map(|_| rng.range(1, 16)).collect();
    v.sort_unstable();
    v.dedup();
    v
}

#[test]
fn prop_batcher_no_loss_no_reorder() {
    let mut rng = Prng::new(0xABCD);
    for case in 0..CASES {
        let policy = BatchPolicy {
            max_wait: Duration::ZERO,
            max_batch: rng.range(1, 12),
        };
        let mut b = DynamicBatcher::new(KindId(0), vec![1, 2, 4, 8], policy);
        let n = rng.range(1, 60);
        for i in 0..n {
            b.push(mk_req(i as u64));
        }
        let mut seen: Vec<u64> = Vec::new();
        while !b.is_empty() {
            let batch = b.cut();
            assert!(batch.bucket >= batch.requests.len().min(8), "case {case}");
            assert!(batch.requests.len() <= batch.bucket, "case {case}");
            seen.extend(batch.requests.iter().map(|r| r.id.0));
        }
        let want: Vec<u64> = (0..n as u64).collect();
        assert_eq!(seen, want, "case {case}: loss or reorder");
    }
}

#[test]
fn prop_bucket_is_smallest_sufficient() {
    // fixed ladder: exhaustive over queue depths
    let b = DynamicBatcher::new(KindId(0), vec![1, 2, 4, 8], BatchPolicy::default());
    for n in 1..=20usize {
        let bucket = b.bucket_for(n);
        if n <= 8 {
            assert!(bucket >= n);
            // no smaller compiled bucket would fit
            for smaller in [1usize, 2, 4, 8] {
                if smaller < bucket {
                    assert!(smaller < n, "n={n}: bucket {bucket} not minimal");
                }
            }
        } else {
            assert_eq!(bucket, 8, "overflow clamps to max bucket");
        }
    }
    // random ladders: the chosen bucket is the minimum sufficient one
    let mut rng = Prng::new(0xB0CCE);
    for case in 0..CASES {
        let buckets = random_buckets(&mut rng);
        let b = DynamicBatcher::new(KindId(0), buckets.clone(), BatchPolicy::default());
        let max = *buckets.last().unwrap();
        for n in 1..=(max + 3) {
            let chosen = b.bucket_for(n);
            let want = buckets.iter().copied().find(|&x| x >= n).unwrap_or(max);
            assert_eq!(chosen, want, "case {case}: n={n} buckets={buckets:?}");
        }
    }
}

#[test]
fn prop_cut_padding_matches_bucket_minus_len() {
    // the `padded` metric the worker records is `bucket - requests.len()`;
    // verify the batch geometry that drives it on random queue depths
    let mut rng = Prng::new(0xFACADE);
    for case in 0..CASES {
        let buckets = random_buckets(&mut rng);
        let max = *buckets.last().unwrap();
        let cap = rng.range(1, max + 4);
        let policy = BatchPolicy { max_wait: Duration::ZERO, max_batch: cap };
        let mut b = DynamicBatcher::new(KindId(0), buckets.clone(), policy);
        let n = rng.range(1, 40);
        for i in 0..n {
            b.push(mk_req(i as u64));
        }
        let mut left = n;
        while !b.is_empty() {
            let batch = b.cut();
            // cut takes min(queue, effective cap) in arrival order
            assert_eq!(batch.requests.len(), left.min(cap.min(max)), "case {case}");
            // chosen bucket is the smallest compiled bucket ≥ the cut size,
            // so worker-side padding is exactly `bucket - requests.len()`
            let want_bucket =
                buckets.iter().copied().find(|&x| x >= batch.requests.len()).unwrap_or(max);
            assert_eq!(batch.bucket, want_bucket, "case {case}");
            let padding = batch.bucket - batch.requests.len();
            if buckets.contains(&batch.requests.len()) {
                assert_eq!(padding, 0, "case {case}: exact-fit cut must not pad");
            }
            left -= batch.requests.len();
        }
        assert_eq!(left, 0, "case {case}: requests lost");
    }
}

#[test]
fn prop_no_request_waits_past_max_wait_plus_tick() {
    // replay random arrival schedules against a virtual clock: every
    // request must be dispatched within max_wait + one dispatch tick of
    // its enqueue time (the serving loop's latency bound)
    let mut rng = Prng::new(0x71C4);
    for case in 0..CASES {
        let base = Instant::now();
        let tick = Duration::from_millis(1);
        let max_wait = Duration::from_millis(rng.range(0, 20) as u64);
        let cap = rng.range(1, 10);
        let policy = BatchPolicy { max_wait, max_batch: cap };
        let mut b = DynamicBatcher::new(KindId(0), vec![1, 2, 4, 8], policy);

        // arrivals at random millisecond offsets in [0, 50)
        let n = rng.range(1, 40);
        let mut arrivals: Vec<(u64, u64)> =
            (0..n as u64).map(|id| (rng.range(0, 50) as u64, id)).collect();
        arrivals.sort_unstable();

        let mut dispatched: Vec<(u64, u64)> = Vec::new(); // (id, dispatch_ms)
        let mut next = 0usize;
        let mut t_ms = 0u64;
        while next < arrivals.len() || !b.is_empty() {
            let now = base + Duration::from_millis(t_ms);
            while next < arrivals.len() && arrivals[next].0 <= t_ms {
                let (at, id) = arrivals[next];
                b.push(mk_req_at(id, base + Duration::from_millis(at)));
                next += 1;
            }
            while b.ready(now) {
                let batch = b.cut();
                for r in batch.requests {
                    dispatched.push((r.id.0, t_ms));
                }
            }
            t_ms += 1;
            assert!(t_ms < 10_000, "case {case}: virtual clock ran away");
        }

        assert_eq!(dispatched.len(), n, "case {case}: requests lost");
        let arrival_of: std::collections::BTreeMap<u64, u64> =
            arrivals.iter().map(|&(at, id)| (id, at)).collect();
        let bound_ms = max_wait.as_millis() as u64 + tick.as_millis() as u64;
        for (id, at_ms) in dispatched {
            let waited = at_ms - arrival_of[&id];
            assert!(
                waited <= bound_ms,
                "case {case}: request {id} waited {waited}ms > {bound_ms}ms"
            );
        }
    }
}

fn random_json(rng: &mut Prng, depth: usize) -> Json {
    match if depth == 0 { rng.range(0, 2) } else { rng.range(0, 4) } {
        0 => Json::Num((rng.f64() * 2000.0 - 1000.0 * 0.5).round() / 8.0),
        1 => Json::Bool(rng.f64() < 0.5),
        2 => Json::Str(format!("s{}-\"q\"\n", rng.below(1000))),
        3 => Json::Arr((0..rng.range(0, 4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = BTreeMap::new();
            for i in 0..rng.range(0, 4) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Prng::new(0x5EED);
    for case in 0..200 {
        let v = random_json(&mut rng, 3);
        let text = json::to_string(&v);
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}");
    }
}

#[test]
fn prop_open_loop_schedule_deterministic() {
    // same seed ⇒ identical Poisson arrival schedule + tag stream;
    // different seed ⇒ a different schedule (the run is genuinely seeded)
    let mut rng = Prng::new(0x09E4);
    for case in 0..CASES {
        let seed = rng.next_u64();
        let rate = rng.f64_range(10.0, 5000.0);
        let n = rng.range(1, 64);
        let a = loadgen::open_plan(seed, rate, n);
        let b = loadgen::open_plan(seed, rate, n);
        assert_eq!(a, b, "case {case}: same seed diverged");
        // offsets strictly positive and nondecreasing
        let mut prev = 0.0;
        for &(t, _) in &a {
            assert!(t >= prev, "case {case}: schedule went backwards");
            prev = t;
        }
        assert!(a[0].0 > 0.0, "case {case}");
        let c = loadgen::open_plan(seed ^ 0xDEAD_BEEF, rate, n);
        assert_ne!(a, c, "case {case}: different seeds gave the same schedule");
    }
    // zero rate degenerates to back-to-back arrivals at t = 0
    let z = loadgen::open_plan(7, 0.0, 4);
    assert!(z.iter().all(|&(t, _)| t == 0.0));
}

#[test]
fn prop_closed_loop_order_deterministic() {
    // each closed-loop worker's request order is a pure function of
    // (seed, worker): same seed ⇒ identical per-worker tag sequences,
    // and distinct workers draw from decorrelated streams
    let mut rng = Prng::new(0xC105ED);
    for case in 0..CASES {
        let seed = rng.next_u64();
        let workers = rng.range(1, 8);
        let n = rng.range(1, 64);
        for w in 0..workers {
            let a = loadgen::closed_tags(seed, w, n);
            let b = loadgen::closed_tags(seed, w, n);
            assert_eq!(a, b, "case {case} worker {w}: same seed diverged");
            if n >= 4 {
                // short streams could collide by chance; 4+ tags cannot
                // realistically (P ≈ 9973⁻⁴)
                assert_ne!(
                    a,
                    loadgen::closed_tags(seed ^ 1, w, n),
                    "case {case} worker {w}: seed ignored"
                );
            }
        }
        if workers >= 2 && n >= 8 {
            assert_ne!(
                loadgen::closed_tags(seed, 0, n),
                loadgen::closed_tags(seed, 1, n),
                "case {case}: workers share one stream"
            );
        }
    }
}

#[test]
fn prop_least_loaded_dispatch_never_starves() {
    // model the batching loop: every dispatch goes to a minimal-load
    // hosting lane, dispatched work drains at random — over any such
    // schedule every hosting lane keeps receiving work
    let mut rng = Prng::new(0x14AE5);
    for case in 0..CASES {
        let n = rng.range(2, 6);
        let mut hosts = vec![false; n];
        for h in hosts.iter_mut() {
            *h = rng.f64() < 0.7;
        }
        hosts[rng.below(n)] = true; // at least one host
        let mut loads = vec![0usize; n];
        let mut picks = vec![0usize; n];
        for step in 0..200 {
            let i = pick_lane(&loads, |i| hosts[i])
                .unwrap_or_else(|| panic!("case {case} step {step}: no lane picked"));
            assert!(hosts[i], "case {case}: dispatched to a non-hosting lane");
            let min_host_load = loads
                .iter()
                .enumerate()
                .filter(|&(j, _)| hosts[j])
                .map(|(_, &l)| l)
                .min()
                .unwrap();
            assert_eq!(
                loads[i], min_host_load,
                "case {case} step {step}: not least-loaded"
            );
            loads[i] += rng.range(1, 2); // the batch lands
            picks[i] += 1;
            // a random lane drains a little
            let j = rng.below(n);
            loads[j] = loads[j].saturating_sub(1);
        }
        for (i, &host) in hosts.iter().enumerate() {
            if host {
                assert!(picks[i] > 0, "case {case}: lane {i} starved");
            } else {
                assert_eq!(picks[i], 0, "case {case}: non-host lane {i} got work");
            }
        }
    }
}
