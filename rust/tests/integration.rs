//! Cross-module integration: the `api` facade over tuner + simulator,
//! config loader → simulator, trace output on simulated runs.

use parframe::api::{Session, Workload};
use parframe::config::{CpuPlatform, RunConfig};
use parframe::models;
use parframe::sim::{self, SimOptions};
use parframe::trace;
use parframe::tuner;
use parframe::PallasError;

#[test]
fn facade_tune_agrees_with_direct_tuner() {
    // the facade is a veneer, not a fork: Session::tune must recommend
    // exactly what tuner::tune recommends, for every zoo model
    let session = Session::on(CpuPlatform::large2());
    for name in models::model_names() {
        let w = Workload::single(name).unwrap();
        let plan = session.tune(&w).unwrap();
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let direct = tuner::tune(&g, &CpuPlatform::large2()).config;
        assert_eq!(plan.entries[0].config, direct, "{name}");
        // and the predicted latency is the direct simulation, bit for bit
        let direct_lat = sim::simulate(&g, &CpuPlatform::large2(), &direct).unwrap().latency_s;
        assert_eq!(
            plan.entries[0].predicted_latency_s.to_bits(),
            direct_lat.to_bits(),
            "{name}"
        );
    }
}

#[test]
fn facade_errors_are_typed_end_to_end() {
    let session = Session::on(CpuPlatform::large2());
    assert!(matches!(
        Workload::single("bert"),
        Err(PallasError::UnknownModel(m)) if m == "bert"
    ));
    assert!(matches!(
        Session::builder().platform_named("tpu"),
        Err(PallasError::UnknownPlatform(_))
    ));
    assert!(matches!(
        Session::builder().policy_named("fifo"),
        Err(PallasError::UnknownPolicy(_))
    ));
    let bad = session.manual_config(Some(0), None, None);
    assert!(matches!(bad, Err(PallasError::InvalidConfig(_))));
}

#[test]
fn config_file_roundtrip_drives_simulation() {
    let text = r#"{
        "platform": "large",
        "inter_op_pools": 2,
        "mkl_threads": 12,
        "intra_op_threads": 12,
        "operator_impl": "matmul2",
        "math_lib": "mkl-dnn",
        "pool_lib": "folly"
    }"#;
    let cfg = RunConfig::from_json_str(text).unwrap();
    let g = models::build("inception_v3", 16).unwrap();
    let r = sim::simulate(&g, &cfg.platform, &cfg.framework).unwrap();
    assert!(r.latency_s > 0.0);
}

#[test]
fn tuner_output_feeds_simulator_everywhere() {
    for name in models::model_names() {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        for p in [CpuPlatform::small(), CpuPlatform::large2()] {
            let t = tuner::tune(&g, &p);
            let r = sim::simulate(&g, &p, &t.config).unwrap();
            assert!(r.latency_s.is_finite() && r.latency_s > 0.0, "{name} on {}", p.name);
        }
    }
}

#[test]
fn ascii_and_chrome_traces_from_simulation() {
    let p = CpuPlatform::small();
    let g = models::build("squeezenet", 16).unwrap();
    let t = tuner::tune(&g, &p);
    let r = sim::simulate_opts(&g, &p, &t.config, &SimOptions { record_timelines: true }).unwrap();
    let ascii = trace::ascii_trace(&r.timelines, r.latency_s, 60);
    assert!(ascii.lines().count() >= 2);
    let chrome = trace::chrome_trace(&r.timelines);
    let parsed = parframe::util::json::Json::parse(&chrome).unwrap();
    assert!(!parsed.as_arr().unwrap().is_empty());
}

#[test]
fn simulated_throughput_scales_with_batch() {
    // larger batches amortise framework overhead: items/s should rise
    let p = CpuPlatform::large();
    let lat = |b: usize| {
        let g = models::build("resnet50", b).unwrap();
        let t = tuner::tune(&g, &p);
        sim::simulate(&g, &p, &t.config).unwrap().throughput(b)
    };
    let t1 = lat(1);
    let t16 = lat(16);
    assert!(t16 > t1, "batch-16 throughput {t16} <= batch-1 {t1}");
}

#[test]
fn end_to_end_sim_story_inception() {
    // the Fig. 1 narrative as an integration check: each tuning step helps
    let p = CpuPlatform::large();
    let g = models::build("inception_v3", 16).unwrap();
    use parframe::config::{FrameworkConfig, OperatorImpl};
    let base = FrameworkConfig {
        inter_op_pools: 1,
        mkl_threads: p.logical_cores(),
        intra_op_threads: 1,
        operator_impl: OperatorImpl::Serial,
        ..FrameworkConfig::tuned_default()
    };
    let step2 = FrameworkConfig { inter_op_pools: 2, mkl_threads: 24, ..base.clone() };
    let step3 = FrameworkConfig {
        intra_op_threads: 24,
        operator_impl: OperatorImpl::IntraOpParallel,
        ..step2.clone()
    };
    let guided = tuner::tune(&g, &p).config;
    let l0 = sim::simulate(&g, &p, &base).unwrap().latency_s;
    let l1 = sim::simulate(&g, &p, &step2).unwrap().latency_s;
    let l2 = sim::simulate(&g, &p, &step3).unwrap().latency_s;
    let l3 = sim::simulate(&g, &p, &guided).unwrap().latency_s;
    assert!(l1 < l0, "inter-op step should help: {l0} -> {l1}");
    assert!(l2 < l1, "intra-op step should help: {l1} -> {l2}");
    assert!(l3 <= l2 * 1.001, "guideline should be at least as good: {l2} -> {l3}");
    assert!(l0 / l3 > 1.5, "total win {:.2}x", l0 / l3);
}
