//! Integration: the three real thread pools under adversarial load
//! (beyond the per-pool unit tests) — ordering, stress, nested submits.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use parframe::config::PoolLib;
use parframe::libs::threadpool::{make_pool, scatter_gather, Task, WaitGroup};

fn tasks(counter: &Arc<AtomicUsize>, n: usize) -> Vec<Task> {
    (0..n)
        .map(|_| {
            let c = Arc::clone(counter);
            Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            }) as Task
        })
        .collect()
}

#[test]
fn stress_50k_tasks_each_pool() {
    for lib in PoolLib::ALL {
        let pool = make_pool(lib, 4);
        let counter = Arc::new(AtomicUsize::new(0));
        scatter_gather(pool.as_ref(), tasks(&counter, 50_000));
        assert_eq!(counter.load(Ordering::Relaxed), 50_000, "{lib:?}");
    }
}

#[test]
fn repeated_waves_drain_cleanly() {
    for lib in PoolLib::ALL {
        let pool = make_pool(lib, 3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..20 {
            scatter_gather(pool.as_ref(), tasks(&counter, 500));
        }
        assert_eq!(counter.load(Ordering::Relaxed), 10_000, "{lib:?}");
    }
}

#[test]
fn uneven_task_durations_balance() {
    // mix of long and short tasks: completion requires work distribution
    for lib in PoolLib::ALL {
        let pool = make_pool(lib, 4);
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(100);
        for i in 0..100usize {
            let c = Arc::clone(&counter);
            let h = wg.handle();
            pool.execute(Box::new(move || {
                if i % 10 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                c.fetch_add(1, Ordering::Relaxed);
                h.done();
            }));
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100, "{lib:?}");
    }
}

#[test]
fn deep_nested_submission() {
    // each task spawns a child; the pool must not deadlock on recursion
    for lib in PoolLib::ALL {
        let pool = make_pool(lib, 2);
        let wg = WaitGroup::new(64);
        fn spawn_chain(
            pool: Arc<dyn parframe::libs::threadpool::TaskPool>,
            wg: WaitGroup,
            depth: usize,
        ) {
            let p2 = Arc::clone(&pool);
            pool.execute(Box::new(move || {
                wg.done();
                if depth > 0 {
                    let wg2 = wg.handle();
                    spawn_chain(p2, wg2, depth - 1);
                }
            }));
        }
        // 8 chains of depth 8 = 64 completions
        for _ in 0..8 {
            spawn_chain(Arc::clone(&pool), wg.handle(), 7);
        }
        wg.wait();
    }
}

#[test]
fn drop_with_pending_work_completes_or_discards_safely() {
    // dropping a pool mid-stream must not hang or crash
    for lib in PoolLib::ALL {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = make_pool(lib, 2);
            for _ in 0..1000 {
                let c = Arc::clone(&counter);
                pool.execute(Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }));
            }
            // drop immediately: pools drain on shutdown
        }
        let done = counter.load(Ordering::Relaxed);
        assert!(done <= 1000, "{lib:?}: {done}");
    }
}
