//! Trace-store integration: capture on a live coordinator → columnar
//! `.plt` round-trip → replay and trace-driven tuning.
//!
//! The property pins are the subsystem's two contracts: encode→decode is
//! *byte*-identical for any event stream (wrapping-delta varints make
//! every `u64` representable), and a replayed trace re-issues the
//! recorded per-kind arrival sequence exactly.

use std::path::PathBuf;
use std::sync::Arc;

use parframe::api::{Session, Workload};
use parframe::config::CpuPlatform;
use parframe::tracestore::{ReplayPlan, TraceData, TraceEvent, TraceRecorder};
use parframe::util::prng::Prng;

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("parframe_{}_{name}", std::process::id()))
}

/// Random events over the full value range of every column — arbitrary
/// `u64` timestamps (not even monotone) must survive the codec.
fn random_events(rng: &mut Prng, n: usize) -> Vec<TraceEvent> {
    (0..n)
        .map(|i| TraceEvent {
            request_id: i as u64,
            kind: (rng.next_u64() % 7) as u16,
            lane: (rng.next_u64() % 5) as u16,
            batch_id: rng.next_u64() % 1000,
            occupancy: rng.next_u64() as u16,
            bucket: rng.next_u64() as u32,
            arrival_ns: rng.next_u64(),
            cut_ns: rng.next_u64(),
            dispatch_ns: rng.next_u64(),
            complete_ns: rng.next_u64(),
        })
        .collect()
}

#[test]
fn random_traces_round_trip_byte_identically() {
    let kinds: Vec<String> = (0..7).map(|i| format!("kind-{i}")).collect();
    let mut rng = Prng::new(0x7A11A5);
    for n in [0usize, 1, 2, 17, 513] {
        let trace = TraceData::new(kinds.clone(), random_events(&mut rng, n));
        let bytes = trace.to_bytes();
        let decoded = TraceData::from_bytes(&bytes).unwrap();
        assert_eq!(decoded, trace, "decode must reproduce the trace (n={n})");
        assert_eq!(decoded.to_bytes(), bytes, "re-encode must be byte-identical (n={n})");
    }
}

#[test]
fn recorder_bounds_memory_and_counts_drops() {
    let ev = |i: u64| TraceEvent {
        request_id: i,
        kind: 0,
        lane: 0,
        batch_id: 0,
        occupancy: 1,
        bucket: 1,
        arrival_ns: i,
        cut_ns: i + 1,
        dispatch_ns: i + 2,
        complete_ns: i + 3,
    };
    // capacity 32 over 16 shards → 2 slots in lane 0's shard
    let r = TraceRecorder::with_capacity(32);
    r.record(0, (0..100).map(ev));
    let s = r.stats();
    assert_eq!(s.recorded, 100);
    assert_eq!(s.buffered, 2);
    assert_eq!(s.dropped, 98);
    // the ring keeps the *newest* window
    let drained = r.drain();
    assert_eq!(drained.len(), 2);
    assert_eq!(drained[0].request_id, 98);
    assert_eq!(drained[1].request_id, 99);
}

#[test]
fn serving_captures_a_consistent_trace() {
    let session = Session::on(CpuPlatform::large2());
    // without a recorder the handle has no trace to drain
    let bare = session.serve_unplanned(&["wide_deep"], 1).unwrap();
    assert!(bare.drain_trace().is_err());
    drop(bare);

    let recorder = Arc::new(TraceRecorder::new());
    let handle =
        session.serve_unplanned_with(&["wide_deep"], 2, Some(Arc::clone(&recorder))).unwrap();
    let report = handle.run_closed("wide_deep", 64, 4).unwrap();
    assert_eq!(report.completed, 64);
    let trace = handle.drain_trace().unwrap();
    assert_eq!(trace.kinds, vec!["wide_deep".to_string()]);
    assert_eq!(trace.events.len(), 64);
    for e in &trace.events {
        assert!(e.arrival_ns <= e.cut_ns, "arrival after cut: {e:?}");
        assert!(e.cut_ns <= e.dispatch_ns, "cut after dispatch: {e:?}");
        assert!(e.dispatch_ns <= e.complete_ns, "dispatch after complete: {e:?}");
    }
    // per-batch occupancies account for every request exactly once
    let occ_sum: usize = trace.batch_rows().iter().map(|&(_, _, occ, _)| occ as usize).sum();
    assert_eq!(occ_sum, 64);

    // the capture round-trips through an actual .plt file
    let path = tmp_path("capture.plt");
    trace.save(&path).unwrap();
    let loaded = TraceData::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, trace);

    let s = trace.summary();
    assert_eq!(s.events, 64);
    assert!(s.batches >= 1 && s.lanes >= 1);
    assert_eq!(s.kinds.len(), 1);
    assert_eq!(s.kinds[0].name, "wide_deep");
    assert_eq!(s.kinds[0].count, 64);
}

#[test]
fn replay_reissues_the_recorded_kind_sequence() {
    let session = Session::on(CpuPlatform::large2());
    // a synthetic arrival process interleaving two kinds at 0.2 ms spacing
    let mut rng = Prng::new(7);
    let arrivals: Vec<(f64, u16)> =
        (0..40).map(|i| (i as f64 * 2e-4, (rng.next_u64() % 2) as u16)).collect();
    let plan = ReplayPlan {
        kinds: vec!["wide_deep".into(), "ncf".into()],
        arrivals: arrivals.clone(),
        seed: 0x5EED,
    };
    let recorder = Arc::new(TraceRecorder::new());
    let handle =
        session.serve_unplanned_with(&["wide_deep", "ncf"], 2, Some(recorder)).unwrap();
    let report = handle.run_replay(&plan).unwrap();
    assert_eq!(report.completed, 40);
    assert_eq!(report.errors, 0);

    let trace = handle.drain_trace().unwrap();
    assert_eq!(trace.events.len(), 40);
    // the coordinator interned its kinds in declaration order, so the
    // captured ids are directly comparable to the plan's
    let want: Vec<u16> = arrivals.iter().map(|&(_, k)| k).collect();
    let got: Vec<u16> = trace.events.iter().map(|e| e.kind).collect();
    assert_eq!(got, want, "replay must re-issue the recorded kind sequence exactly");
    // and a plan extracted from the capture carries the same sequence
    // forward (arrival order, offsets non-decreasing from zero)
    let extracted = trace.replay_plan(1);
    let again: Vec<u16> = extracted.arrivals.iter().map(|&(_, k)| k).collect();
    assert_eq!(again, want);
    assert_eq!(extracted.arrivals[0].0, 0.0);
    assert!(extracted.arrivals.windows(2).all(|w| w[0].0 <= w[1].0));

    // a replay naming an unserved kind fails loudly
    let bad = ReplayPlan {
        kinds: vec!["resnet50".into()],
        arrivals: vec![(0.0, 0)],
        seed: 1,
    };
    assert!(handle.run_replay(&bad).is_err());
}

#[test]
fn tune_from_trace_is_deterministic_across_jobs() {
    let ev = |id: u64, kind: u16, bucket: u32| TraceEvent {
        request_id: id,
        kind,
        lane: 0,
        batch_id: id,
        occupancy: 1,
        bucket,
        arrival_ns: id * 1_000,
        cut_ns: id * 1_000 + 100,
        dispatch_ns: id * 1_000 + 200,
        complete_ns: id * 1_000 + 900,
    };
    // 6 wide_deep requests at bucket 4, 2 ncf at bucket 2
    let mut events: Vec<TraceEvent> = (0..6).map(|i| ev(i, 0, 4)).collect();
    events.extend((6..8).map(|i| ev(i, 1, 2)));
    let trace = TraceData::new(vec!["wide_deep".into(), "ncf".into()], events);
    let w = Workload::from_trace(&trace).unwrap();
    assert_eq!(w.entries[0].kind, "wide_deep");
    assert_eq!(w.entries[0].weight, 6.0);
    assert_eq!(w.entries[0].batch, 4); // mode bucket, not canonical
    assert_eq!(w.entries[1].batch, 2);

    // the full tune --trace pipeline is bit-identical at any --jobs
    let mut outputs = Vec::new();
    for jobs in [1usize, 4] {
        let session = Session::builder().platform(CpuPlatform::small()).jobs(jobs).build();
        let plan = session.tune_exhaustive(&w).unwrap();
        let score = session.score_plan_on_trace(&plan, &trace).unwrap();
        outputs.push((plan.group_lines(), score.to_bits()));
    }
    assert_eq!(outputs[0], outputs[1], "tune --trace must be bit-identical across --jobs");
}
