//! Integration: the pluggable operator-scheduling policy layer, threaded
//! from the sim engine through the tuner to the serving lanes.
//!
//! The headline claim (after Liu et al., arXiv 1810.08955): ready-op
//! dispatch priority is a real performance lever on wide graphs once ≥ 2
//! inter-op pools compete for more ready operators than there are free
//! pools — and the knob is tunable at every tier of the stack.

use parframe::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use parframe::metrics::{KindWindow, WindowSnapshot};
use parframe::models;
use parframe::sched::LanePlan;
use parframe::sim;
use parframe::tuner::{self, exhaustive_search, OnlineTuner};

fn cfg(pools: usize, threads: usize, policy: SchedPolicy) -> FrameworkConfig {
    FrameworkConfig {
        inter_op_pools: pools,
        mkl_threads: threads,
        intra_op_threads: threads,
        operator_impl: OperatorImpl::IntraOpParallel,
        sched_policy: policy,
        ..FrameworkConfig::tuned_default()
    }
}

const WIDE_MODELS: [&str; 5] =
    ["inception_v1", "inception_v2", "inception_v3", "googlenet", "transformer"];

#[test]
fn critical_path_strictly_beats_topo_on_a_wide_zoo_model() {
    // scan the wide zoo × pool counts; critical-path dispatch must win
    // strictly somewhere (it structurally should on the transformer,
    // whose decoder chain sits behind 24 topologically-earlier cross-KV
    // shards, and on inception's uneven branches)
    let p = CpuPlatform::large2();
    let mut wins = Vec::new();
    for name in WIDE_MODELS {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        for pools in [2usize, 3, 4, 6] {
            let threads = p.physical_cores() / pools;
            let topo = sim::simulate(&g, &p, &cfg(pools, threads, SchedPolicy::Topo))
                .unwrap()
                .latency_s;
            let cp = sim::simulate(&g, &p, &cfg(pools, threads, SchedPolicy::CriticalPathFirst))
                .unwrap()
                .latency_s;
            assert!(cp.is_finite() && cp > 0.0, "{name}/{pools} pools");
            if cp < topo * 0.999 {
                wins.push(format!("{name}/{pools}p: {:.3}x", topo / cp));
            }
        }
    }
    assert!(
        !wins.is_empty(),
        "critical-path dispatch never strictly beat topo on any wide model"
    );
    println!("critical-path wins: {wins:?}");
}

#[test]
fn critical_path_never_collapses_on_wide_models() {
    // the policy may tie topo where ordering freedom is narrow, but it
    // must never make a wide graph meaningfully slower — that would mean
    // the rank computation is feeding the heap garbage
    let p = CpuPlatform::large2();
    for name in WIDE_MODELS {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let pools = tuner::tune(&g, &p).config.inter_op_pools.max(2);
        let threads = p.physical_cores() / pools;
        let topo =
            sim::simulate(&g, &p, &cfg(pools, threads, SchedPolicy::Topo)).unwrap().latency_s;
        let cp = sim::simulate(&g, &p, &cfg(pools, threads, SchedPolicy::CriticalPathFirst))
            .unwrap()
            .latency_s;
        assert!(cp <= topo * 1.10, "{name}: cp={cp} topo={topo}");
    }
}

#[test]
fn exhaustive_optimum_never_worse_than_best_single_policy() {
    // the policy dimension only widens the search space: the swept
    // optimum must be ≤ the best latency of each policy at the §8 point
    let p = CpuPlatform::large();
    let g = models::build("inception_v2", 16).unwrap();
    let opt = exhaustive_search(&g, &p).unwrap().best_latency_s;
    for policy in SchedPolicy::ALL {
        let guided = tuner::tune(&g, &p).config;
        let lat = sim::simulate(&g, &p, &FrameworkConfig { sched_policy: policy, ..guided })
            .unwrap()
            .latency_s;
        assert!(opt <= lat * 1.0001, "{policy:?}: opt={opt} point={lat}");
    }
}

fn window(kinds: &[(&str, u64)]) -> WindowSnapshot {
    WindowSnapshot {
        elapsed_s: 1.0,
        kinds: kinds
            .iter()
            .map(|(k, n)| KindWindow {
                kind: (*k).into(),
                arrivals: *n,
                completed: *n,
                batches: n / 4,
                batch_items: *n,
            })
            .collect(),
    }
}

#[test]
fn online_tuner_scores_policy_and_replans_under_surge() {
    // the dispatch policy is a live dimension of the online tuner's
    // scoring (flipping it moves the predicted cost), and a surge toward
    // the wide kind triggers a re-plan drawn from the policy-aware
    // candidate set (the flip neighbors themselves are unit-tested in
    // tuner::online)
    let platform = CpuPlatform::large2();
    let kinds = ["transformer", "resnet50"];
    let mut t = OnlineTuner::new(platform.clone(), &kinds);
    t.observe(&window(&[("transformer", 72), ("resnet50", 8)]));
    t.observe(&window(&[("transformer", 72), ("resnet50", 8)]));
    let current = LanePlan::guideline(&platform, &kinds)
        .unwrap()
        .with_policy(SchedPolicy::Topo);

    // policy changes the score: the transformer group's 24 cross-KV
    // shards reorder against its decoder chain under 4 pools, so the two
    // schedules cannot coincide
    let cpf = current.clone().with_policy(SchedPolicy::CriticalPathFirst);
    assert_ne!(t.score(&cpf), t.score(&current), "policy must move the predicted cost");

    let next = t.propose(&current).unwrap().expect("strong shift should re-plan");
    let tr = next.group_for("transformer").unwrap();
    let rn = next.group_for("resnet50").unwrap();
    assert!(
        tr.allocation.cores > rn.allocation.cores,
        "surge kind got {} cores vs {}",
        tr.allocation.cores,
        rn.allocation.cores
    );
    next.validate().unwrap();
    assert!(t.score(&next) < t.score(&current));
}

#[test]
fn pinned_policy_changes_sim_backend_latency_table() {
    // `serve --policy` pins the policy through the backend contract
    // (SimBackendConfig::policy — thread knobs stay per-bucket tuned):
    // the pre-simulated lane tables must reflect it
    use parframe::runtime::{SimBackend, SimBackendConfig};
    let p = CpuPlatform::large2();
    let kind = "transformer";
    let table_for = |policy: SchedPolicy| {
        let mut sc = SimBackendConfig::new(p.clone(), &[kind]);
        sc.policy = Some(policy);
        SimBackend::new(sc).unwrap()
    };
    let topo = table_for(SchedPolicy::Topo);
    let cp = table_for(SchedPolicy::CriticalPathFirst);
    let mut any_diff = false;
    for bucket in [1usize, 2, 4, 8] {
        let a = topo.simulated_latency(kind, bucket).unwrap();
        let b = cp.simulated_latency(kind, bucket).unwrap();
        assert!(a > 0.0 && b > 0.0);
        any_diff |= a != b;
    }
    assert!(any_diff, "policy pin had no effect on any bucket's latency table");
}
