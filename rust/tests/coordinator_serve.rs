//! End-to-end coordinator tests: real requests through router → batcher →
//! PJRT worker lanes, verifying batching invariants on live numerics.
//!
//! Skipped (with a notice) when `make artifacts` has not run.

use std::path::Path;
use std::time::Duration;

use parframe::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use parframe::runtime::{gen_input, ModelRuntime, Tensor};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping coordinator tests: artifacts/ not built");
        None
    }
}

fn mlp_coordinator(max_wait_ms: u64) -> Option<Coordinator> {
    let dir = artifacts_dir()?;
    let mut cfg = CoordinatorConfig::for_kind(dir, "mlp");
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(max_wait_ms), max_batch: usize::MAX };
    Some(Coordinator::start(cfg).expect("start coordinator"))
}

fn item(tag: u32) -> Tensor {
    gen_input(tag, &[1, 256], 1.0)
}

#[test]
fn single_request_roundtrip() {
    let Some(c) = mlp_coordinator(1) else { return };
    let resp = c.infer("mlp", item(7)).unwrap();
    let out = resp.output.expect("inference ok");
    assert_eq!(out.shape, vec![1, 8]);
    assert!(out.data.iter().all(|v| v.is_finite()));
    assert_eq!(c.metrics().requests.get(), 1);
}

#[test]
fn batched_equals_unbatched() {
    // The §2.2.3 invariant: riding a batch must not change a request's
    // numerics (beyond f32 noise).
    let Some(c) = mlp_coordinator(20) else { return };
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load_some(dir, |e| e.name == "mlp_b1").unwrap();

    // submit 4 distinct requests quickly so they share one batch
    let rxs: Vec<_> = (0..4).map(|t| c.submit("mlp", item(20 + t)).unwrap()).collect();
    for (t, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let got = resp.output.expect("ok");
        let solo = rt.execute_x("mlp_b1", item(20 + t as u32)).unwrap();
        for (a, b) in got.data.iter().zip(solo.data.iter()) {
            assert!((a - b).abs() < 1e-4, "req {t}: {a} vs {b}");
        }
        assert!(resp.bucket >= 1);
    }
    // 4 requests in ≤ 2 dispatches proves batching actually happened
    assert!(c.metrics().batches.get() <= 2, "batches={}", c.metrics().batches.get());
    assert!(c.metrics().mean_batch_size() >= 2.0);
}

#[test]
fn burst_of_requests_all_answered() {
    let Some(c) = mlp_coordinator(2) else { return };
    let rxs: Vec<_> = (0..25).map(|t| c.submit("mlp", item(t)).unwrap()).collect();
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.is_ok(), "{:?}", resp.output.err());
        ok += 1;
    }
    assert_eq!(ok, 25);
    assert_eq!(c.metrics().requests.get(), 25);
    // buckets are at most 8, so at least ceil(25/8) = 4 batches
    assert!(c.metrics().batches.get() >= 4);
}

#[test]
fn rejects_malformed_input() {
    let Some(c) = mlp_coordinator(1) else { return };
    let bad = Tensor { shape: vec![1, 3], data: vec![0.0; 3] };
    assert!(c.submit("mlp", bad).is_err());
    let unknown = Tensor { shape: vec![1, 256], data: vec![0.0; 256] };
    assert!(c.submit("resnet", unknown).is_err());
}

#[test]
fn padding_tracked_for_partial_batches() {
    let Some(c) = mlp_coordinator(1) else { return };
    // 3 requests into buckets {1,2,4,8} ⇒ bucket 4 with 1 padded row
    let rxs: Vec<_> = (0..3).map(|t| c.submit("mlp", item(t)).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    // padding happens unless the batcher split 3 = 2 + 1 exactly
    let padded = c.metrics().padded.get();
    let batches = c.metrics().batches.get();
    assert!(padded > 0 || batches >= 2, "padded={padded} batches={batches}");
}

#[test]
fn two_lanes_share_load() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = CoordinatorConfig::for_kind(dir, "mlp");
    cfg.lanes = 2;
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(1), max_batch: 2 };
    let c = Coordinator::start(cfg).expect("start");
    let rxs: Vec<_> = (0..12).map(|t| c.submit("mlp", item(t)).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().is_ok());
    }
    assert_eq!(c.metrics().requests.get(), 12);
    assert!(c.metrics().batches.get() >= 6); // max_batch 2 ⇒ ≥6 dispatches
}

#[test]
fn transformer_family_served_too() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = CoordinatorConfig::for_kind(dir, "transformer");
    let c = Coordinator::start(cfg).expect("start");
    let shape = c.router().item_shape("transformer").unwrap().clone();
    let seq_input = gen_input(11, &[shape.rows_per_item, shape.feature_dims[0]], 0.5);
    let resp = c.infer("transformer", seq_input).unwrap();
    let out = resp.output.expect("ok");
    assert_eq!(out.shape[0], shape.rows_per_item);
    assert!(out.data.iter().all(|v| v.is_finite()));
}
