//! End-to-end coordinator tests: real requests through router → batcher →
//! worker lanes, verifying batching invariants on live executions.
//!
//! The primary suite runs UNCONDITIONALLY on the simulation backend
//! (deterministic numerics + simulated batch latencies, zero external
//! artifacts). The PJRT variants at the bottom still probe for
//! `make artifacts` output and skip with a notice when it is absent.

use std::path::Path;
use std::time::Duration;

use parframe::config::CpuPlatform;
use parframe::coordinator::{loadgen, BatchPolicy, Coordinator, CoordinatorConfig, LoadgenConfig};
use parframe::runtime::{
    gen_input, Backend, ModelRuntime, SimBackend, SimBackendConfig, Tensor, SIM_OUT_FEATURES,
};

// ---------------------------------------------------------------------------
// sim-backed suite (tier-1: always runs)
// ---------------------------------------------------------------------------

fn sim_coordinator(kinds: &[&str], max_wait_ms: u64) -> Coordinator {
    let mut cfg = CoordinatorConfig::sim(CpuPlatform::large(), kinds);
    cfg.policy =
        BatchPolicy { max_wait: Duration::from_millis(max_wait_ms), max_batch: usize::MAX };
    Coordinator::start(cfg).expect("start sim coordinator")
}

fn item(tag: u32) -> Tensor {
    gen_input(tag, &[1, 64], 1.0)
}

#[test]
fn single_request_roundtrip() {
    let c = sim_coordinator(&["wide_deep"], 1);
    let resp = c.infer("wide_deep", item(7)).unwrap();
    let out = resp.output.expect("inference ok");
    assert_eq!(out.shape, vec![1, SIM_OUT_FEATURES]);
    assert!(out.data.iter().all(|v| v.is_finite()));
    assert!(resp.execute_s > 0.0, "simulated batch latency recorded");
    assert_eq!(c.metrics().requests.get(), 1);
    assert_eq!(c.metrics().execute_latency.count(), 1);
}

#[test]
fn batched_equals_unbatched() {
    // The §2.2.3 invariant: riding a batch must not change a request's
    // numerics. On the sim backend the projection is row-local, so the
    // match is exact.
    let c = sim_coordinator(&["wide_deep"], 20);
    let solo = SimBackend::new(SimBackendConfig::new(CpuPlatform::large(), &["wide_deep"]))
        .expect("sim backend");

    // submit 4 distinct requests quickly so they share one batch
    let rxs: Vec<_> = (0..4).map(|t| c.submit("wide_deep", item(20 + t)).unwrap()).collect();
    for (t, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let got = resp.output.expect("ok");
        let want = solo.execute("wide_deep", 1, &item(20 + t as u32)).unwrap().output;
        assert_eq!(got.data, want.data, "req {t}");
        assert!(resp.bucket >= 1);
    }
    // 4 requests in ≤ 2 dispatches proves batching actually happened
    assert!(c.metrics().batches.get() <= 2, "batches={}", c.metrics().batches.get());
    assert!(c.metrics().mean_batch_size() >= 2.0);
}

#[test]
fn burst_of_requests_all_answered() {
    let c = sim_coordinator(&["wide_deep"], 2);
    let rxs: Vec<_> = (0..25).map(|t| c.submit("wide_deep", item(t)).unwrap()).collect();
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.is_ok(), "{:?}", resp.output.err());
        ok += 1;
    }
    assert_eq!(ok, 25);
    assert_eq!(c.metrics().requests.get(), 25);
    // buckets are at most 8, so at least ceil(25/8) = 4 batches
    assert!(c.metrics().batches.get() >= 4);
}

#[test]
fn rejects_malformed_input() {
    let c = sim_coordinator(&["wide_deep"], 1);
    let bad = Tensor { shape: vec![1, 3], data: vec![0.0; 3] };
    assert!(c.submit("wide_deep", bad).is_err());
    let unknown = Tensor { shape: vec![1, 64], data: vec![0.0; 64] };
    assert!(c.submit("resnet50", unknown).is_err());
}

#[test]
fn padding_tracked_for_partial_batches() {
    let c = sim_coordinator(&["wide_deep"], 1);
    // 3 requests into buckets {1,2,4,8} ⇒ bucket 4 with 1 padded row
    let rxs: Vec<_> = (0..3).map(|t| c.submit("wide_deep", item(t)).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    // padding happens unless the batcher split 3 = 2 + 1 exactly
    let padded = c.metrics().padded.get();
    let batches = c.metrics().batches.get();
    assert!(padded > 0 || batches >= 2, "padded={padded} batches={batches}");
}

#[test]
fn two_lanes_share_load() {
    let mut cfg = CoordinatorConfig::sim(CpuPlatform::large(), &["wide_deep"]);
    cfg.lanes = 2;
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(1), max_batch: 2 };
    let c = Coordinator::start(cfg).expect("start");
    let rxs: Vec<_> = (0..12).map(|t| c.submit("wide_deep", item(t)).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().is_ok());
    }
    assert_eq!(c.metrics().requests.get(), 12);
    assert!(c.metrics().batches.get() >= 6); // max_batch 2 ⇒ ≥6 dispatches
}

#[test]
fn transformer_family_served_too() {
    let c = sim_coordinator(&["transformer"], 1);
    let shape = c.router().item_shape("transformer").unwrap().clone();
    assert_eq!(shape.rows_per_item, 32);
    let seq_input = gen_input(11, &shape.dims(), 0.5);
    let resp = c.infer("transformer", seq_input).unwrap();
    let out = resp.output.expect("ok");
    assert_eq!(out.shape[0], shape.rows_per_item);
    assert!(out.data.iter().all(|v| v.is_finite()));
}

#[test]
fn multiple_kinds_one_coordinator() {
    let c = sim_coordinator(&["wide_deep", "ncf"], 1);
    assert_eq!(c.router().kinds(), vec!["ncf", "wide_deep"]);
    let a = c.infer("wide_deep", item(1)).unwrap();
    let b = c.infer("ncf", item(2)).unwrap();
    assert!(a.is_ok() && b.is_ok());
    // same input features, different models ⇒ different simulated latency
    assert_ne!(a.execute_s, b.execute_s);
    assert_eq!(c.metrics().requests.get(), 2);
}

#[test]
fn metrics_histograms_populated() {
    let c = sim_coordinator(&["wide_deep"], 1);
    for t in 0..10 {
        assert!(c.infer("wide_deep", item(t)).unwrap().is_ok());
    }
    let m = c.metrics();
    assert_eq!(m.request_latency.count(), 10);
    assert_eq!(m.queue_latency.count(), 10);
    assert!(m.execute_latency.count() >= 1);
    assert!(m.request_latency.percentile(99.0) >= m.request_latency.percentile(50.0));
    // end-to-end latency includes the simulated model time
    assert!(m.request_latency.percentile(50.0) > 0.0);
}

#[test]
fn closed_loop_loadgen_drives_full_path() {
    let c = sim_coordinator(&["wide_deep"], 1);
    let report = loadgen::run(&c, &LoadgenConfig::closed("wide_deep", 64, 4)).unwrap();
    assert_eq!(report.completed, 64);
    assert_eq!(report.errors, 0);
    assert!(report.throughput_rps > 0.0);
    assert!(report.model_p99_ms >= report.model_p50_ms);
    assert!(report.model_p50_ms > 0.0, "simulated latency flows into the report");
    assert!(report.mean_batch >= 1.0);
    assert_eq!(c.metrics().requests.get(), 64);
}

#[test]
fn open_loop_loadgen_drives_full_path() {
    let c = sim_coordinator(&["wide_deep"], 2);
    let report =
        loadgen::run(&c, &LoadgenConfig::open("wide_deep", 40, 4000.0).with_seed(11)).unwrap();
    assert_eq!(report.completed, 40);
    assert_eq!(report.errors, 0);
    assert!(report.elapsed_s > 0.0);
    assert!(report.wall_p99_ms >= report.wall_p50_ms);
}

#[test]
fn loadgen_rejects_unserved_kind() {
    let c = sim_coordinator(&["wide_deep"], 1);
    assert!(loadgen::run(&c, &LoadgenConfig::closed("resnet50", 4, 1)).is_err());
}

// ---------------------------------------------------------------------------
// PJRT variants (need `make artifacts`; skip with a notice otherwise)
// ---------------------------------------------------------------------------

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping PJRT coordinator tests: artifacts/ not built");
        None
    }
}

fn mlp_coordinator(max_wait_ms: u64) -> Option<Coordinator> {
    let dir = artifacts_dir()?;
    let mut cfg = CoordinatorConfig::pjrt(dir, &["mlp"]);
    cfg.policy =
        BatchPolicy { max_wait: Duration::from_millis(max_wait_ms), max_batch: usize::MAX };
    Some(Coordinator::start(cfg).expect("start coordinator"))
}

fn pjrt_item(tag: u32) -> Tensor {
    gen_input(tag, &[1, 256], 1.0)
}

#[test]
fn pjrt_single_request_roundtrip() {
    let Some(c) = mlp_coordinator(1) else { return };
    let resp = c.infer("mlp", pjrt_item(7)).unwrap();
    let out = resp.output.expect("inference ok");
    assert_eq!(out.shape, vec![1, 8]);
    assert!(out.data.iter().all(|v| v.is_finite()));
    assert_eq!(c.metrics().requests.get(), 1);
}

#[test]
fn pjrt_batched_equals_unbatched() {
    let Some(c) = mlp_coordinator(20) else { return };
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load_some(dir, |e| e.name == "mlp_b1").unwrap();

    let rxs: Vec<_> = (0..4).map(|t| c.submit("mlp", pjrt_item(20 + t)).unwrap()).collect();
    for (t, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let got = resp.output.expect("ok");
        let solo = rt.execute_x("mlp_b1", pjrt_item(20 + t as u32)).unwrap();
        for (a, b) in got.data.iter().zip(solo.data.iter()) {
            assert!((a - b).abs() < 1e-4, "req {t}: {a} vs {b}");
        }
        assert!(resp.bucket >= 1);
    }
    assert!(c.metrics().batches.get() <= 2, "batches={}", c.metrics().batches.get());
    assert!(c.metrics().mean_batch_size() >= 2.0);
}

#[test]
fn pjrt_burst_of_requests_all_answered() {
    let Some(c) = mlp_coordinator(2) else { return };
    let rxs: Vec<_> = (0..25).map(|t| c.submit("mlp", pjrt_item(t)).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().is_ok());
    }
    assert_eq!(c.metrics().requests.get(), 25);
    assert!(c.metrics().batches.get() >= 4);
}

#[test]
fn pjrt_transformer_family_served_too() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = CoordinatorConfig::pjrt(dir, &["transformer"]);
    let c = Coordinator::start(cfg).expect("start");
    let shape = c.router().item_shape("transformer").unwrap().clone();
    let seq_input = gen_input(11, &shape.dims(), 0.5);
    let resp = c.infer("transformer", seq_input).unwrap();
    let out = resp.output.expect("ok");
    assert_eq!(out.shape[0], shape.rows_per_item);
    assert!(out.data.iter().all(|v| v.is_finite()));
}
