//! Fast-plane equivalence suite: the rebuilt serving data path (interned
//! kinds, batched ingress drain, recycled batch buffers) must be
//! response-bit-identical to the seed loop, which is preserved behind
//! `CoordinatorConfig::with_reference_loop(true)` as the reference plane.
//!
//! Everything runs on `SimBackend` (batching-invariant numerics), so the
//! comparisons are exact regardless of how arrivals happen to batch.

use std::time::{Duration, Instant};

use parframe::config::CpuPlatform;
use parframe::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use parframe::coordinator::request::{Request, RequestId};
use parframe::coordinator::{
    loadgen, BatchPool, Coordinator, CoordinatorConfig, LoadgenConfig, BATCH_POOL_CAP,
};
use parframe::runtime::{gen_input, KindId, Tensor};
use parframe::sched::LanePlan;
use parframe::util::prng::Prng;

const KINDS: [&str; 3] = ["wide_deep", "ncf", "transformer"];

fn config(core_aware: bool, reference: bool) -> CoordinatorConfig {
    let platform = CpuPlatform::large2();
    let mut cfg = CoordinatorConfig::sim(platform.clone(), &KINDS);
    cfg.lanes = 2;
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(2), max_batch: usize::MAX };
    if core_aware {
        cfg = cfg.with_plan(LanePlan::guideline(&platform, &KINDS).expect("guideline plan"));
    }
    cfg.with_reference_loop(reference)
}

/// Drive the same tagged request schedule through a coordinator and
/// return `(kind, tag, output rows)` per request, in submit order.
fn drive(coord: &Coordinator) -> Vec<(String, u32, Vec<f32>)> {
    let mut pending = Vec::new();
    for round in 0..6u32 {
        for kind in KINDS {
            let dims = coord.router().item_shape(kind).unwrap().dims();
            for t in 0..4u32 {
                let tag = round * 100 + t;
                let rx = coord.submit(kind, gen_input(tag, &dims, 1.0)).unwrap();
                pending.push((kind.to_string(), tag, rx));
            }
        }
    }
    pending
        .into_iter()
        .map(|(kind, tag, rx)| {
            let resp = rx.recv().expect("response");
            let out = resp.output.unwrap_or_else(|e| panic!("{kind}/{tag}: {e}"));
            (kind, tag, out.data)
        })
        .collect()
}

/// The pinned acceptance test: fast plane responses are bit-identical to
/// the seed loop for every zoo kind, under both lane regimes.
#[test]
fn fastpath_matches_reference_plane_bit_exact() {
    for core_aware in [false, true] {
        let fast = Coordinator::start(config(core_aware, false)).unwrap();
        let seed = Coordinator::start(config(core_aware, true)).unwrap();
        let got = drive(&fast);
        let want = drive(&seed);
        assert_eq!(got.len(), want.len());
        for ((k_f, t_f, out_f), (k_s, t_s, out_s)) in got.iter().zip(&want) {
            assert_eq!((k_f, t_f), (k_s, t_s), "schedule skew (core_aware={core_aware})");
            assert_eq!(out_f, out_s, "{k_f}/{t_f} diverged (core_aware={core_aware})");
        }
        assert_eq!(fast.metrics().requests.get(), seed.metrics().requests.get());
    }
}

fn mk_req(id: u64, kind: KindId, enqueued: Instant) -> Request {
    let (tx, _rx) = std::sync::mpsc::channel();
    Request {
        id: RequestId(id),
        kind,
        input: Tensor { shape: vec![1, 4], data: vec![0.0; 4] },
        enqueued,
        reply: tx,
    }
}

/// Replay random multi-kind arrival schedules against a virtual clock
/// through both ingress disciplines — the seed's one-at-a-time enqueue
/// with allocating `cut()` vs the fast drain with pooled `cut_into()` —
/// and require identical per-kind batch membership and bucket choices.
#[test]
fn prop_fast_drain_matches_seed_loop_batches() {
    let n_kinds = 3usize;
    let mut rng = Prng::new(0xFA57);
    for case in 0..40 {
        let base = Instant::now();
        let policy = BatchPolicy {
            max_wait: Duration::from_millis(rng.range(0, 8) as u64),
            max_batch: rng.range(1, 12),
        };
        let mk = |kind: usize| {
            DynamicBatcher::new(KindId(kind as u16), vec![1, 2, 4, 8], policy.clone())
        };
        let mut seed_batchers: Vec<DynamicBatcher> = (0..n_kinds).map(&mk).collect();
        let mut fast_batchers: Vec<DynamicBatcher> = (0..n_kinds).map(&mk).collect();
        let pool = BatchPool::new(BATCH_POOL_CAP);

        // arrivals: (ms offset, kind, id), sorted by time
        let n = rng.range(1, 60);
        let mut arrivals: Vec<(u64, usize, u64)> = (0..n as u64)
            .map(|id| (rng.range(0, 40) as u64, rng.below(n_kinds), id))
            .collect();
        arrivals.sort_unstable();

        // per-kind (member ids, bucket) sequences from each discipline
        let mut seed_cuts: Vec<Vec<(Vec<u64>, usize)>> = vec![Vec::new(); n_kinds];
        let mut fast_cuts: Vec<Vec<(Vec<u64>, usize)>> = vec![Vec::new(); n_kinds];
        let mut next = 0usize;
        let mut t_ms = 0u64;
        loop {
            // the fast loop drains the whole backlog before cutting; the
            // seed loop enqueued one message per try_recv — both see the
            // same set once the tick's arrivals are in
            while next < arrivals.len() && arrivals[next].0 <= t_ms {
                let (at, kind, id) = arrivals[next];
                let when = base + Duration::from_millis(at);
                seed_batchers[kind].push(mk_req(id, KindId(kind as u16), when));
                fast_batchers[kind].push(mk_req(id, KindId(kind as u16), when));
                next += 1;
            }
            let now = base + Duration::from_millis(t_ms);
            for kind in 0..n_kinds {
                while seed_batchers[kind].ready(now) {
                    let b = seed_batchers[kind].cut();
                    seed_cuts[kind].push((b.requests.iter().map(|r| r.id.0).collect(), b.bucket));
                }
                while fast_batchers[kind].ready(now) {
                    let b = fast_batchers[kind].cut_into(pool.take());
                    fast_cuts[kind].push((b.requests.iter().map(|r| r.id.0).collect(), b.bucket));
                    pool.put(b.recycle());
                }
            }
            if next >= arrivals.len() && fast_batchers.iter().all(|b| b.is_empty()) {
                break;
            }
            t_ms += 1;
            assert!(t_ms < 10_000, "case {case}: virtual clock ran away");
        }
        assert_eq!(seed_cuts, fast_cuts, "case {case}: cut schedule diverged");
        let total: usize = fast_cuts.iter().flatten().map(|(ids, _)| ids.len()).sum();
        assert_eq!(total, n, "case {case}: requests lost");
        assert_eq!(pool.stats().outstanding(), 0, "case {case}: pooled buffer leaked");
    }
}

/// A lone request under a quiet coordinator must ship once `max_wait`
/// expires, in the smallest bucket — the drain rebuild must not have
/// broken the latency bound for stalled arrivals.
#[test]
fn stalled_arrival_ships_at_max_wait() {
    let platform = CpuPlatform::large();
    let mut cfg = CoordinatorConfig::sim(platform, &["wide_deep"]);
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(25), max_batch: usize::MAX };
    let coord = Coordinator::start(cfg).unwrap();
    let dims = coord.router().item_shape("wide_deep").unwrap().dims();
    let resp = coord.infer("wide_deep", gen_input(1, &dims, 1.0)).unwrap();
    assert!(resp.output.is_ok());
    assert_eq!(resp.bucket, 1, "lone request must ride the smallest bucket");
    assert!(
        resp.queue_s >= 0.015,
        "lone request dispatched after {}s — before the max-wait bound",
        resp.queue_s
    );
}

/// Live re-planning must neither leak nor double-return pooled buffers:
/// after load + `apply_plan` + load + full drain, every taken buffer has
/// come back and the idle pool respects its cap.
#[test]
fn apply_plan_leaks_no_pooled_buffers() {
    let platform = CpuPlatform::large2();
    let plan_a = LanePlan::guideline(&platform, &["wide_deep", "ncf"]).unwrap();
    let mix = [("wide_deep".to_string(), 0.2), ("ncf".to_string(), 0.8)];
    let plan_b = LanePlan::for_mix(&platform, &mix).unwrap();

    let cfg = CoordinatorConfig::sim(platform, &["wide_deep", "ncf"]).with_plan(plan_a);
    let coord = Coordinator::start(cfg).unwrap();
    let pool = coord.batch_pool();

    let r = loadgen::run(&coord, &LoadgenConfig::closed("wide_deep", 64, 4)).unwrap();
    assert_eq!(r.errors, 0);
    coord.apply_plan(plan_b).expect("re-plan under a warm pool");
    let r = loadgen::run(&coord, &LoadgenConfig::closed("ncf", 64, 4)).unwrap();
    assert_eq!(r.errors, 0);

    drop(coord); // joins the loop and every lane: all buffers must be home
    let s = pool.stats();
    assert_eq!(s.outstanding(), 0, "leaked batch buffers: {s:?}");
    assert!(s.pooled <= BATCH_POOL_CAP, "pool over cap: {s:?}");
}

/// Steady-state dispatch runs on recycled buffers (fast plane), while the
/// reference plane's zero-cap pool never retains one — and the interned
/// submit path answers identically to the string path.
#[test]
fn pool_recycles_on_fast_plane_only() {
    let fast = Coordinator::start(config(false, false)).unwrap();
    let r = loadgen::run(&fast, &LoadgenConfig::closed("wide_deep", 128, 8)).unwrap();
    assert_eq!(r.errors, 0);
    let s = fast.pool_stats();
    assert!(s.reused > 0, "steady-state cuts should reuse pooled buffers: {s:?}");

    let id = fast.kind_table().resolve("ncf").expect("interned");
    let dims = fast.router().item_shape("ncf").unwrap().dims();
    let by_id = fast.infer_id(id, gen_input(9, &dims, 1.0)).unwrap().output.unwrap();
    let by_name = fast.infer("ncf", gen_input(9, &dims, 1.0)).unwrap().output.unwrap();
    assert_eq!(by_id.data, by_name.data, "interned submit diverged from string submit");

    let seed = Coordinator::start(config(false, true)).unwrap();
    let r = loadgen::run(&seed, &LoadgenConfig::closed("wide_deep", 64, 8)).unwrap();
    assert_eq!(r.errors, 0);
    let pool = seed.batch_pool();
    drop(seed);
    let s = pool.stats();
    assert_eq!(s.reused, 0, "reference plane must not recycle: {s:?}");
    assert_eq!(s.pooled, 0, "reference plane must not retain buffers: {s:?}");
    assert_eq!(s.outstanding(), 0, "reference plane leaked buffers: {s:?}");
}
