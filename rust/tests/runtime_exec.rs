//! Integration: load AOT artifacts via PJRT and verify numerics against
//! the python-computed digests (the cross-language correctness check).
//!
//! Requires `make artifacts` to have run; tests no-op with a notice when
//! the artifacts directory is absent (e.g. bare `cargo test` in CI).

use std::path::Path;

use parframe::runtime::{gen_input, ModelRuntime, Tensor};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping runtime tests: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn loads_all_artifacts_and_verifies_digests() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(dir).expect("load artifacts");
    assert!(rt.loaded().len() >= 8, "loaded: {:?}", rt.loaded());
    for name in rt.loaded().into_iter().map(str::to_string).collect::<Vec<_>>() {
        rt.self_check(&name).unwrap_or_else(|e| panic!("{name}: {e:?}"));
    }
}

#[test]
fn mlp_batch_rows_independent() {
    // the invariant that makes dynamic batching legal: row i of a batched
    // execution equals the single-row execution of row i
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load_some(dir, |e| e.kind == "mlp").expect("load");
    let b4 = rt.manifest().artifact_for("mlp", 4).unwrap().clone();
    let full_in = b4.inputs[0].generate();
    let full = rt.execute_x(&b4.name, full_in.clone()).unwrap();
    let cols = b4.output_shape[1];
    let in_dim = b4.inputs[0].shape[1];

    let b1 = rt.manifest().artifact_for("mlp", 1).unwrap().clone();
    for row in 0..2 {
        let row_in = Tensor {
            shape: vec![1, in_dim],
            data: full_in.data[row * in_dim..(row + 1) * in_dim].to_vec(),
        };
        let row_out = rt.execute_x(&b1.name, row_in).unwrap();
        for c in 0..cols {
            let a = full.data[row * cols + c];
            let b = row_out.data[c];
            assert!((a - b).abs() < 1e-4, "row {row} col {c}: {a} vs {b}");
        }
    }
}

#[test]
fn matmul_artifact_matches_host_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load_some(dir, |e| e.name == "matmul_128").expect("load");
    let entry = rt.manifest().get("matmul_128").unwrap().clone();
    let x = entry.inputs[0].generate();
    let w = entry.inputs[1].generate();
    let out = rt.execute("matmul_128", &[x.clone(), w.clone()]).unwrap();
    // host-side reference for a few entries
    let n = 128;
    for (r, c) in [(0usize, 0usize), (3, 7), (127, 127)] {
        let mut acc = 0f64;
        for k in 0..n {
            acc += x.data[r * n + k] as f64 * w.data[k * n + c] as f64;
        }
        let got = out.data[r * n + c] as f64;
        assert!((got - acc).abs() < 1e-3, "({r},{c}): {got} vs {acc}");
    }
}

#[test]
fn gen_input_is_deterministic() {
    let a = gen_input(3, &[64, 64], 0.125);
    let b = gen_input(3, &[64, 64], 0.125);
    assert_eq!(a, b);
}

#[test]
fn execute_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load_some(dir, |e| e.kind == "mlp").expect("load");
    let bad = Tensor { shape: vec![1, 8], data: vec![0.0; 8] };
    assert!(rt.execute_x("mlp_b1", bad).is_err());
    assert!(rt.execute("mlp_b1", &[]).is_err()); // wrong arity
    assert!(rt.execute("nope", &[]).is_err());
}
