//! End-to-end tests for core-aware lane scheduling + online re-tuning:
//! a shifting two-model mix on `large.2` where the adaptive plan must
//! beat the startup-frozen §8 configuration, plus plan/property checks
//! (allocations never overlap, re-plans never drop in-flight requests).
//!
//! Everything runs on `SimBackend` — per-batch latencies are simulated
//! under each lane's *allocated* cores, so moving cores to the hot model
//! shows up deterministically in `Response::execute_s`.

use parframe::config::CpuPlatform;
use parframe::coordinator::{loadgen, Coordinator, CoordinatorConfig, MixPhase, MixReport};
use parframe::runtime::{gen_input, SimBackend, SimBackendConfig};
use parframe::sched::LanePlan;
use parframe::tuner::{OnlineTuner, OnlineTunerConfig};
use parframe::util::prng::Prng;

/// Light model that drains away.
const COLD: &str = "wide_deep";
/// Heavy model that ramps up.
const HOT: &str = "resnet50";

/// The shift: one cold-heavy phase, then the traffic inverts and stays
/// inverted (the ramp's steady tail is what the plans are compared on).
fn shift_phases() -> Vec<MixPhase> {
    let mut phases = vec![MixPhase::new(&[(COLD, 0.9), (HOT, 0.1)], 48)];
    for _ in 0..3 {
        phases.push(MixPhase::new(&[(COLD, 0.1), (HOT, 0.9)], 64));
    }
    phases
}

fn start(platform: &CpuPlatform, plan: LanePlan) -> Coordinator {
    let cfg = CoordinatorConfig::sim(platform.clone(), &[COLD, HOT]).with_plan(plan);
    Coordinator::start(cfg).expect("start planned coordinator")
}

/// Drive the shift via `loadgen::run_shift` (the same code path the CLI
/// and the serving example use); 8 closed-loop workers keep the hot
/// kind's batches at the top bucket, where the re-tuned core split pays
/// off fully. Returns per-phase reports.
fn drive(coord: &Coordinator, tuner: Option<&mut OnlineTuner>) -> Vec<MixReport> {
    let reports =
        loadgen::run_shift(coord, &shift_phases(), 8, 0xACE, tuner).expect("shift runs");
    for (i, r) in reports.iter().enumerate() {
        assert_eq!(r.overall.errors, 0, "phase {i} had errors");
    }
    reports
}

#[test]
fn adaptive_beats_frozen_under_load_shift() {
    let platform = CpuPlatform::large2();
    let initial = LanePlan::guideline(&platform, &[COLD, HOT]).unwrap();

    // frozen: the startup §8 plan serves the whole shift
    let frozen_coord = start(&platform, initial.clone());
    let frozen = drive(&frozen_coord, None);

    // adaptive: windows feed the online re-tuner between phases
    let adaptive_coord = start(&platform, initial.clone());
    let mut tuner = OnlineTuner::with_config(
        platform.clone(),
        &[COLD, HOT],
        OnlineTunerConfig { smoothing: 0.7, ..OnlineTunerConfig::default() },
    );
    let adaptive = drive(&adaptive_coord, Some(&mut tuner));

    // the re-tuner must have moved cores toward the hot model
    let final_plan = adaptive_coord.current_plan().expect("planned");
    let hot_cores = final_plan.group_for(HOT).unwrap().allocation.cores;
    let initial_hot_cores = initial.group_for(HOT).unwrap().allocation.cores;
    assert!(
        hot_cores > initial_hot_cores,
        "adaptive plan kept {hot_cores} cores for the hot model (started at {initial_hot_cores})"
    );

    // post-shift steady phase: the hot model must run ≥ 1.1x faster on
    // the adaptive plan (simulated latency under the lane's cores), and
    // its tail must not regress
    let f = frozen[3].kind(HOT).expect("hot kind served");
    let a = adaptive[3].kind(HOT).expect("hot kind served");
    assert!(f.completed > 0 && a.completed > 0);
    assert!(
        a.model_mean_ms * 1.1 <= f.model_mean_ms,
        "adaptive hot-kind mean {:.3}ms not ≥1.1x better than frozen {:.3}ms",
        a.model_mean_ms,
        f.model_mean_ms
    );
    assert!(
        a.model_p99_ms <= f.model_p99_ms,
        "adaptive p99 {:.3}ms worse than frozen {:.3}ms",
        a.model_p99_ms,
        f.model_p99_ms
    );
    // same request stream on both coordinators
    assert_eq!(
        frozen.iter().map(|r| r.overall.completed).sum::<usize>(),
        adaptive.iter().map(|r| r.overall.completed).sum::<usize>(),
    );
}

#[test]
fn planned_lane_executes_under_allocated_cores() {
    // a lane's Response::execute_s must equal the simulated latency on
    // the lane's restricted platform — and differ from the whole-machine
    // latency the pre-plan coordinator would have reported
    let platform = CpuPlatform::large2();
    let plan = LanePlan::guideline(&platform, &[COLD, HOT]).unwrap();
    let group = plan.group_for(HOT).unwrap();
    let slice =
        platform.restrict(group.allocation.first_core, group.allocation.cores);
    let mut expect_cfg = SimBackendConfig::new(slice, &[HOT]);
    expect_cfg.framework = Some(group.framework.clone());
    let expected = SimBackend::new(expect_cfg)
        .unwrap()
        .simulated_latency(HOT, 1)
        .unwrap();

    let coord = start(&platform, plan);
    let resp = coord.infer(HOT, gen_input(3, &[1, 64], 1.0)).unwrap();
    assert!(resp.is_ok());
    assert_eq!(resp.execute_s, expected, "lane simulated on its slice");

    let whole = SimBackend::new(SimBackendConfig::new(platform, &[HOT]))
        .unwrap()
        .simulated_latency(HOT, 1)
        .unwrap();
    assert_ne!(
        resp.execute_s, whole,
        "restricting the lane's cores must change its simulated latency"
    );
}

#[test]
fn apply_plan_keeps_in_flight_requests() {
    let platform = CpuPlatform::large2();
    let initial = LanePlan::guideline(&platform, &[COLD, HOT]).unwrap();
    let coord = start(&platform, initial);

    // queue work, flip the plan mid-flight, then collect every response
    let mut rxs = Vec::new();
    for t in 0..16 {
        rxs.push(coord.submit(COLD, gen_input(t, &[1, 64], 1.0)).unwrap());
        rxs.push(coord.submit(HOT, gen_input(t + 100, &[1, 64], 1.0)).unwrap());
    }
    let flipped = LanePlan::for_mix(
        &platform,
        &[(COLD.to_string(), 0.1), (HOT.to_string(), 0.9)],
    )
    .unwrap();
    coord.apply_plan(flipped.clone()).unwrap();
    assert_eq!(coord.current_plan().unwrap(), flipped);

    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(resp.is_ok(), "{:?}", resp.output.err());
    }
    assert_eq!(coord.metrics().requests.get(), 32);

    // the swapped-in lanes serve new traffic too
    assert!(coord.infer(HOT, gen_input(7, &[1, 64], 1.0)).unwrap().is_ok());
}

#[test]
fn apply_plan_rejects_uncovered_kinds() {
    let platform = CpuPlatform::large2();
    let initial = LanePlan::guideline(&platform, &[COLD, HOT]).unwrap();
    let coord = start(&platform, initial.clone());
    let partial = LanePlan::guideline(&platform, &[COLD]).unwrap();
    assert!(coord.apply_plan(partial).is_err(), "plan must host every served kind");
    // the old plan stays live
    assert_eq!(coord.current_plan().unwrap(), initial);
    assert!(coord.infer(HOT, gen_input(1, &[1, 64], 1.0)).unwrap().is_ok());
}

#[test]
fn prop_lane_allocations_never_overlap_nor_exceed_machine() {
    // the acceptance property: random mixes on every platform produce
    // plans whose lane allocations are pairwise disjoint and in-bounds
    let zoo = ["wide_deep", "resnet50", "ncf", "transformer", "inception_v3"];
    let platforms =
        [CpuPlatform::small(), CpuPlatform::large(), CpuPlatform::large2()];
    let mut rng = Prng::new(0xA110C);
    for case in 0..60 {
        let platform = &platforms[case % platforms.len()];
        let n = rng.range(1, zoo.len().min(platform.physical_cores()));
        let mut mix: Vec<(String, f64)> =
            zoo[..n].iter().map(|k| (k.to_string(), rng.f64())).collect();
        if rng.f64() < 0.3 {
            mix[0].1 = 0.0; // a drained model keeps its lane
        }
        let mut plan = LanePlan::for_mix(platform, &mix).unwrap_or_else(|e| {
            panic!("case {case} on {}: {e:#}", platform.name)
        });
        // sometimes split a group into several lanes
        if rng.f64() < 0.5 {
            let g = rng.below(plan.groups.len());
            plan.groups[g].lanes = rng.range(1, 4);
        }
        plan.validate().unwrap_or_else(|e| panic!("case {case}: {e:#}"));

        let lanes = plan.lane_assignments();
        let phys = platform.physical_cores();
        let total: usize = lanes.iter().map(|a| a.allocation.cores).sum();
        assert!(total <= phys, "case {case}: {total} cores allocated of {phys}");
        for (i, a) in lanes.iter().enumerate() {
            assert!(a.allocation.cores >= 1, "case {case}: empty lane");
            assert!(
                a.allocation.end() <= phys,
                "case {case}: lane {} ends at {} of {phys}",
                a.lane_id,
                a.allocation.end()
            );
            for b in &lanes[i + 1..] {
                assert!(
                    !a.allocation.overlaps(&b.allocation),
                    "case {case}: lanes {} and {} overlap",
                    a.lane_id,
                    b.lane_id
                );
            }
        }
    }
}
