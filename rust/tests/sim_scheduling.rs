//! Integration: scheduling behaviour of the simulator across models and
//! platforms (paper §4's qualitative findings).

use parframe::config::{CpuPlatform, FrameworkConfig, OperatorImpl};
use parframe::models;
use parframe::sim::{self, Category, SimOptions};

fn cfg(pools: usize, mkl: usize, intra: usize) -> FrameworkConfig {
    FrameworkConfig {
        inter_op_pools: pools,
        mkl_threads: mkl,
        intra_op_threads: intra,
        operator_impl: OperatorImpl::Serial,
        ..FrameworkConfig::tuned_default()
    }
}

#[test]
fn best_pools_never_exceed_max_width() {
    // "the best numbers of pools do not exceed the maximum graph width"
    let p = CpuPlatform::large();
    for name in ["caffenet", "resnet50", "inception_v1", "ncf"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let w = parframe::graph::analyze_width(&g);
        let mut best = (1usize, f64::INFINITY);
        for pools in 1..=6usize {
            let lat = sim::simulate(&g, &p, &cfg(pools, 24 / pools.min(24), 1)).unwrap().latency_s;
            if lat < best.1 {
                best = (pools, lat);
            }
        }
        assert!(best.0 <= w.max_width.max(1), "{name}: best={} width={}", best.0, w.max_width);
    }
}

#[test]
fn sync_scheduling_is_one_pool() {
    // pools=1 must serialise everything: latency ≈ Σ op times
    let p = CpuPlatform::large();
    let g = models::build("caffenet", 16).unwrap();
    let r = sim::simulate_opts(&g, &p, &cfg(1, 24, 1), &SimOptions { record_timelines: true })
        .unwrap();
    // no two segments on different cores may overlap unless same op
    let mut spans: Vec<(f64, f64, usize)> = Vec::new();
    for tl in &r.timelines {
        for s in tl {
            if !matches!(s.cat, Category::Barrier | Category::Idle) {
                spans.push((s.t0, s.t1, s.op));
            }
        }
    }
    spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for w in spans.windows(2) {
        if w[1].0 < w[0].1 - 1e-12 {
            assert_eq!(w[0].2, w[1].2, "ops overlap under sync scheduling");
        }
    }
}

#[test]
fn async_uses_multiple_pools_simultaneously() {
    let p = CpuPlatform::large();
    let g = models::build("ncf", 256).unwrap();
    let r = sim::simulate_opts(&g, &p, &cfg(4, 6, 1), &SimOptions { record_timelines: true })
        .unwrap();
    // embeddings land on different pools concurrently: find overlapping
    // busy segments with different ops
    let mut overlap = false;
    let mut spans: Vec<(f64, f64, usize)> = Vec::new();
    for tl in &r.timelines {
        for s in tl {
            if s.cat == Category::MklCompute {
                spans.push((s.t0, s.t1, s.op));
            }
        }
    }
    for a in &spans {
        for b in &spans {
            if a.2 != b.2 && a.0 < b.1 && b.0 < a.1 {
                overlap = true;
            }
        }
    }
    assert!(overlap, "async pools never overlapped");
}

#[test]
fn over_threading_monotonically_penalised() {
    let p = CpuPlatform::small();
    let g = models::build("inception_v2", 16).unwrap();
    let ok = sim::simulate(&g, &p, &cfg(2, 2, 2)).unwrap().latency_s;
    let over = sim::simulate(&g, &p, &cfg(8, 8, 8)).unwrap().latency_s;
    let way_over = sim::simulate(&g, &p, &cfg(4, 16, 16)).unwrap().latency_s;
    assert!(over > ok);
    assert!(way_over > ok);
}

#[test]
fn training_prefers_two_pools_small_batch() {
    // grad ∥ weight-sum gives chains a 2-pool sweet spot at small batch
    // (paper Fig. 4's table: large batches shrink it again because the
    // gradient outgrows the weight-sum — the imbalance §4.1 describes)
    let p = CpuPlatform::large();
    let fwd = models::build("fc512", 64).unwrap();
    let g = models::to_training_graph(&fwd);
    let one = sim::simulate(&g, &p, &cfg(1, 24, 1)).unwrap().latency_s;
    let two = sim::simulate(&g, &p, &cfg(2, 12, 1)).unwrap().latency_s;
    assert!(two < one, "one={one} two={two}");

    // at large batch the 2-pool advantage shrinks or inverts
    let fwd_big = models::build("fc4k", 2048).unwrap();
    let g_big = models::to_training_graph(&fwd_big);
    let one_b = sim::simulate(&g_big, &p, &cfg(1, 24, 1)).unwrap().latency_s;
    let two_b = sim::simulate(&g_big, &p, &cfg(2, 12, 1)).unwrap().latency_s;
    let small_gain = one / two;
    let big_gain = one_b / two_b;
    assert!(big_gain < small_gain, "small={small_gain} big={big_gain}");
}

#[test]
fn platforms_ordered_by_capability() {
    let g = models::build("resnet50", 16).unwrap();
    let c = |p: &CpuPlatform| {
        let mut c = cfg(1, p.physical_cores(), p.physical_cores());
        c.operator_impl = OperatorImpl::IntraOpParallel;
        sim::simulate(&g, p, &c).unwrap().latency_s
    };
    let small = c(&CpuPlatform::small());
    let large = c(&CpuPlatform::large());
    let large2 = c(&CpuPlatform::large2());
    assert!(small > large, "small={small} large={large}");
    assert!(large > large2, "large={large} large2={large2}");
}

#[test]
fn gflops_never_exceed_platform_peak() {
    for name in ["resnet50", "transformer", "caffenet"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        for p in [CpuPlatform::small(), CpuPlatform::large(), CpuPlatform::large2()] {
            let mut c = cfg(1, p.physical_cores(), 1);
            c.operator_impl = OperatorImpl::IntraOpParallel;
            let r = sim::simulate(&g, &p, &c).unwrap();
            assert!(
                r.gflops <= p.peak_gflops() * 1.001,
                "{name} on {}: {} > {}",
                p.name,
                r.gflops,
                p.peak_gflops()
            );
        }
    }
}
