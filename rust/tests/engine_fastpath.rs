//! Property: every fast-path entry point — the calendar-queue engine
//! (`sim::simulate`), the prepared entry point, and the delta-simulation
//! cache — produces **bit-identical** reports to the seed BinaryHeap
//! engine (`sim::simulate_reference`), across the whole model zoo, on
//! both endpoint platforms, under every scheduling policy, timelines
//! included. Speed that changes the answer doesn't count.

use parframe::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use parframe::models;
use parframe::sim::{self, Category, PreparedGraph, SimCache, SimOptions, SimReport};

fn cfg(platform: &CpuPlatform, pools: usize, policy: SchedPolicy) -> FrameworkConfig {
    let threads = (platform.physical_cores() / pools).max(1);
    FrameworkConfig {
        inter_op_pools: pools,
        mkl_threads: threads,
        intra_op_threads: threads,
        operator_impl: OperatorImpl::IntraOpParallel,
        sched_policy: policy,
        ..FrameworkConfig::tuned_default()
    }
}

/// Bitwise report equality: scalar fields, every breakdown category,
/// and (when `timelines`) every segment of every logical core.
fn assert_bit_identical(tag: &str, got: &SimReport, want: &SimReport, timelines: bool) {
    assert_eq!(got.latency_s.to_bits(), want.latency_s.to_bits(), "{tag}: latency");
    assert_eq!(got.gflops.to_bits(), want.gflops.to_bits(), "{tag}: gflops");
    assert_eq!(got.upi_bytes.to_bits(), want.upi_bytes.to_bits(), "{tag}: upi_bytes");
    assert_eq!(got.upi_peak_bps.to_bits(), want.upi_peak_bps.to_bits(), "{tag}: upi_peak");
    for cat in Category::ALL {
        assert_eq!(
            got.breakdown.get(cat).to_bits(),
            want.breakdown.get(cat).to_bits(),
            "{tag}: breakdown {cat:?}"
        );
    }
    if timelines {
        assert_eq!(got.timelines.len(), want.timelines.len(), "{tag}: core count");
        for (core, (a, b)) in got.timelines.iter().zip(&want.timelines).enumerate() {
            assert_eq!(a.len(), b.len(), "{tag}: core {core} segment count");
            for (sa, sb) in a.iter().zip(b) {
                let same = sa.t0.to_bits() == sb.t0.to_bits()
                    && sa.t1.to_bits() == sb.t1.to_bits()
                    && sa.cat == sb.cat
                    && sa.op == sb.op;
                assert!(same, "{tag}: core {core} segment diverged: {sa:?} vs {sb:?}");
            }
        }
    }
}

#[test]
fn fast_paths_bit_identical_to_seed_engine_across_zoo() {
    let opts = SimOptions { record_timelines: true };
    for p in [CpuPlatform::small(), CpuPlatform::large2()] {
        for name in models::model_names() {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let prep = PreparedGraph::new(&g);
            let cache = SimCache::new();
            for policy in SchedPolicy::ALL {
                let c = cfg(&p, 3, policy);
                let tag = format!("{name}/{}/{policy:?}", p.name);
                let reference = sim::simulate_reference(&g, &p, &c, &opts).unwrap();

                // calendar-queue engine, cold scratch
                let fast = sim::simulate_opts(&g, &p, &c, &opts).unwrap();
                assert_bit_identical(&format!("{tag}/fast"), &fast, &reference, true);

                // prepared entry point, pooled scratch (warm after the
                // first policy — any scratch state must be invisible)
                let prepared = sim::simulate_prepared(&prep, &p, &c, &opts).unwrap();
                assert_bit_identical(&format!("{tag}/prepared"), &prepared, &reference, true);

                // delta-sim cache: first policy builds the family phase
                // table, later siblings replay only the event loop
                let cached = cache.report(&prep, &p, &c).unwrap();
                assert_bit_identical(&format!("{tag}/cached"), &cached, &reference, false);
            }
            assert_eq!(
                cache.delta_fallbacks(),
                0,
                "{name}/{}: phase-table guard rejected a policy sibling",
                p.name
            );
        }
    }
}

#[test]
fn delta_cache_is_arrival_order_independent() {
    // whichever policy sibling arrives first builds the shared phase
    // table; the bits of every sibling's report must not depend on it
    let p = CpuPlatform::large2();
    for name in ["inception_v2", "transformer"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let forward = SimCache::new();
        let reverse = SimCache::new();
        let prep_f = PreparedGraph::new(&g);
        let prep_r = PreparedGraph::new(&g);
        let mut fwd = Vec::new();
        for policy in SchedPolicy::ALL {
            fwd.push(forward.report(&prep_f, &p, &cfg(&p, 4, policy)).unwrap());
        }
        let mut rev = Vec::new();
        for policy in SchedPolicy::ALL.into_iter().rev() {
            rev.push(reverse.report(&prep_r, &p, &cfg(&p, 4, policy)).unwrap());
        }
        rev.reverse();
        for (a, b) in fwd.iter().zip(&rev) {
            assert_bit_identical(name, a, b, false);
        }
        for cache in [&forward, &reverse] {
            assert_eq!(cache.misses(), 3, "{name}");
            assert_eq!(cache.delta_hits(), 2, "{name}");
            assert_eq!(cache.delta_fallbacks(), 0, "{name}");
        }
    }
}

#[test]
fn warm_cache_returns_the_same_bits() {
    // any cache state: a hit must return exactly what the miss stored
    let p = CpuPlatform::small();
    let g = models::build("squeezenet", 16).unwrap();
    let cache = SimCache::new();
    let prep = PreparedGraph::new(&g);
    let c = cfg(&p, 2, SchedPolicy::CriticalPathFirst);
    let miss = cache.report(&prep, &p, &c).unwrap();
    let hit = cache.report(&prep, &p, &c).unwrap();
    assert_bit_identical("squeezenet/warm", &hit, &miss, false);
    assert_eq!(cache.hits(), 1);
}
