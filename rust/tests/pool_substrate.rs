//! Integration: the lock-free pool substrate under adversarial load —
//! shutdown-under-load drains, park/submit races, worker-local
//! recursion, oversubscription, and the batch submission paths.
//! (Chase–Lev steal/take interleavings and eventcount protocol races
//! are covered by unit tests inside `libs::threadpool`.)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use parframe::config::PoolLib;
use parframe::libs::threadpool::{
    make_pool, scatter_gather, EigenPool, ReferencePool, Task, TaskPool, WaitGroup,
};
use parframe::util::prng::Prng;

fn counting_tasks(counter: &Arc<AtomicUsize>, n: usize) -> Vec<Task> {
    (0..n)
        .map(|_| {
            let c = Arc::clone(counter);
            Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            }) as Task
        })
        .collect()
}

#[test]
fn shutdown_under_load_drains_every_task() {
    // Both the lock-free substrate and the reference plane guarantee
    // drain-on-shutdown: dropping the pool mid-stream runs everything
    // already submitted — no task dropped, no hang. Seeded sleeps
    // scatter the drop point across queue states.
    let mut rng = Prng::new(0x9d5_0bad);
    for round in 0..8u64 {
        let n = 2_000 + rng.below(3_000);
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool: Box<dyn TaskPool> = if round % 2 == 0 {
                Box::new(EigenPool::new(1 + rng.below(4)))
            } else {
                Box::new(ReferencePool::new(1 + rng.below(4)))
            };
            for t in counting_tasks(&counter, n) {
                pool.execute(t);
            }
            if rng.below(2) == 1 {
                std::thread::sleep(Duration::from_micros(rng.below(200) as u64));
            }
            // drop with work in flight
        }
        assert_eq!(counter.load(Ordering::Relaxed), n, "round {round}");
    }
}

#[test]
fn park_submit_race_loses_no_wakeup() {
    // Single-task round-trips with seeded idle gaps long enough for
    // workers to park: a lost wakeup would hang the latch (or stall
    // until the 100 ms belt-and-braces timeout fires, blowing the
    // loose elapsed bound below).
    let pool = EigenPool::new(2);
    let mut rng = Prng::new(0xec_5eed);
    let t0 = Instant::now();
    for i in 0..2_000u32 {
        let wg = WaitGroup::new(1);
        let h = wg.handle();
        pool.execute(Box::new(move || h.done()));
        wg.wait();
        if i % 64 == 0 {
            // let the workers spin out and park before the next submit
            std::thread::sleep(Duration::from_micros(100 + rng.below(400) as u64));
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "park/submit loop took {:?} — lost wakeups falling back to the park timeout?",
        t0.elapsed()
    );
}

#[test]
fn deep_worker_recursion_uses_local_deques() {
    // A chain of tasks each spawned from *inside* a worker must land in
    // that worker's own deque (the TLS fast path), not the injector.
    let pool = Arc::new(EigenPool::new(2));
    let wg = WaitGroup::new(1);
    fn chain(pool: Arc<EigenPool>, wg: WaitGroup, depth: usize) {
        let p2 = Arc::clone(&pool);
        pool.execute(Box::new(move || {
            if depth == 0 {
                wg.done();
            } else {
                chain(p2, wg, depth - 1);
            }
        }));
    }
    chain(Arc::clone(&pool), wg.handle(), 200);
    wg.wait();
    assert!(
        pool.local_submits() >= 200,
        "worker-spawned tasks bypassed the local deque: {} local, {} injected",
        pool.local_submits(),
        pool.injected()
    );
}

#[test]
fn oversubscribed_64_threads_on_the_substrate() {
    // the Fig. 14 stress shape on the new pool and the reference plane
    let eigen = EigenPool::new(64);
    assert_eq!(eigen.threads(), 64);
    let counter = Arc::new(AtomicUsize::new(0));
    scatter_gather(&eigen, counting_tasks(&counter, 20_000));
    assert_eq!(counter.load(Ordering::Relaxed), 20_000);

    let reference = ReferencePool::new(64);
    assert_eq!(reference.threads(), 64);
    let counter = Arc::new(AtomicUsize::new(0));
    scatter_gather(&reference, counting_tasks(&counter, 20_000));
    assert_eq!(counter.load(Ordering::Relaxed), 20_000);
}

#[test]
fn batch_paths_run_on_every_flavour() {
    // execute_batch (fire-and-forget) and execute_batch_counted (pool-
    // counted completions) on all four pool flavours
    let mut pools: Vec<(String, Box<dyn TaskPool>)> = PoolLib::ALL
        .into_iter()
        .map(|lib| {
            (format!("{lib:?}"), Box::new(ArcPool(make_pool(lib, 3))) as Box<dyn TaskPool>)
        })
        .collect();
    pools.push(("Reference".into(), Box::new(ReferencePool::new(3))));
    for (name, pool) in &pools {
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(500);
        let tasks: Vec<Task> = (0..500)
            .map(|_| {
                let c = Arc::clone(&counter);
                let h = wg.handle();
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                    h.done();
                }) as Task
            })
            .collect();
        pool.execute_batch(tasks);
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 500, "{name} execute_batch");

        let counter = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(500);
        pool.execute_batch_counted(counting_tasks(&counter, 500), &wg);
        wg.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 500, "{name} execute_batch_counted");
    }
}

/// Adapter so `Arc<dyn TaskPool>` fits in the same list as owned pools.
struct ArcPool(Arc<dyn TaskPool>);

impl TaskPool for ArcPool {
    fn execute(&self, task: Task) {
        self.0.execute(task)
    }
    fn execute_batch(&self, tasks: Vec<Task>) {
        self.0.execute_batch(tasks)
    }
    fn execute_batch_counted(&self, tasks: Vec<Task>, wg: &WaitGroup) {
        self.0.execute_batch_counted(tasks, wg)
    }
    fn threads(&self) -> usize {
        self.0.threads()
    }
}

#[test]
fn nested_scatter_gather_from_worker_context() {
    // An outer batch whose tasks each run an inner scatter_gather on
    // the same pool. Sized so blocked outer tasks never exhaust the
    // workers (2 outer waits on a 4-worker pool) — the same occupancy
    // contract the mutex pool had.
    let pool = Arc::new(EigenPool::new(4));
    let counter = Arc::new(AtomicUsize::new(0));
    let outer_wg = WaitGroup::new(2);
    for _ in 0..2 {
        let p2 = Arc::clone(&pool);
        let c2 = Arc::clone(&counter);
        let h = outer_wg.handle();
        pool.execute(Box::new(move || {
            scatter_gather(p2.as_ref(), counting_tasks(&c2, 16));
            h.done();
        }));
    }
    outer_wg.wait();
    assert_eq!(counter.load(Ordering::Relaxed), 32);
}
