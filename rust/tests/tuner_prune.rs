//! The tentpole property of the branch-and-bound tuner: across the
//! model zoo, on both platform shapes, with or without a policy pin, at
//! any `--jobs` value, the pruned sweep returns the **bit-identical**
//! optimum of the flat sweep — same config, same latency bits, same
//! unique-point count — while `simulated` reports how many points the
//! admissible bound let it skip. And the bound must stay admissible the
//! whole time: `bound_unsound()` counts every simulated point that came
//! in below its analytic lower bound, and it must end at zero.

use std::sync::Arc;

use parframe::config::{CpuPlatform, SchedPolicy};
use parframe::models;
use parframe::sim::SimCache;
use parframe::tuner::{bound_unsound, exhaustive_search_with, SweepOptions, SweepPool};

const ZOO: [&str; 3] = ["wide_deep", "ncf", "squeezenet"];

#[test]
fn pruned_sweep_bit_identical_to_flat_across_zoo() {
    for platform in [CpuPlatform::small(), CpuPlatform::large2()] {
        for name in ZOO {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            for pin in [None, Some(SchedPolicy::Topo)] {
                let flat = exhaustive_search_with(
                    &g,
                    &platform,
                    &SweepOptions::with_jobs(1).prune(false).pinned(pin),
                )
                .unwrap();
                assert_eq!(flat.simulated, flat.evaluated);
                for jobs in [1usize, 4] {
                    // cold cache each time: the pruned sweep must find the
                    // same optimum while actually deciding what to skip,
                    // not by replaying the flat sweep's memo entries
                    let pruned = exhaustive_search_with(
                        &g,
                        &platform,
                        &SweepOptions::with_jobs(jobs).pinned(pin),
                    )
                    .unwrap();
                    let tag = format!("{name}/{}/pin={pin:?}/jobs={jobs}", platform.name);
                    assert_eq!(pruned.best, flat.best, "{tag}: best config diverged");
                    assert_eq!(
                        pruned.best_latency_s.to_bits(),
                        flat.best_latency_s.to_bits(),
                        "{tag}: latency bits diverged"
                    );
                    assert_eq!(pruned.evaluated, flat.evaluated, "{tag}: lattice size diverged");
                    assert!(pruned.simulated <= pruned.evaluated, "{tag}");
                }
            }
        }
    }
    assert_eq!(bound_unsound(), 0, "a simulated point undercut its admissible bound");
}

#[test]
fn pruning_actually_skips_points_on_the_large_platform() {
    // the acceptance workload: a free (unpinned) wide_deep sweep on
    // large.2. jobs=1 makes the best-first order — and therefore the
    // skip count — deterministic.
    let g = models::build("wide_deep", models::canonical_batch("wide_deep")).unwrap();
    let p = CpuPlatform::large2();
    let r = exhaustive_search_with(&g, &p, &SweepOptions::with_jobs(1)).unwrap();
    assert!(
        r.simulated < r.evaluated,
        "branch-and-bound simulated every point: {}/{}",
        r.simulated,
        r.evaluated
    );
    assert_eq!(bound_unsound(), 0);
}

#[test]
fn one_sweep_pool_serves_many_sweeps_bit_identically() {
    // the persistent-executor satellite: two searches over one shared
    // SweepPool spawn exactly one worker pool between them, and neither
    // result drifts from a fresh-pool run
    let p = CpuPlatform::small();
    let pool = Arc::new(SweepPool::new(4));
    let cache = Arc::new(SimCache::new());
    for name in ["ncf", "squeezenet"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let fresh = exhaustive_search_with(&g, &p, &SweepOptions::with_jobs(4)).unwrap();
        let shared = exhaustive_search_with(
            &g,
            &p,
            &SweepOptions::shared(4, Arc::clone(&cache)).on_pool(Arc::clone(&pool)),
        )
        .unwrap();
        assert_eq!(shared.best, fresh.best, "{name}: shared-pool sweep diverged");
        assert_eq!(
            shared.best_latency_s.to_bits(),
            fresh.best_latency_s.to_bits(),
            "{name}: shared-pool latency bits diverged"
        );
    }
    assert_eq!(pool.spawn_count(), 1, "re-sweeps must reuse the one spawned pool");
}
