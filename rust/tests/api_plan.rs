//! The `pallas::api` facade contract: `Plan` JSON round-trips are the
//! identity for every tuning tier, and a plan deployed from a file in a
//! *different process* serves bit-identical latency tables to in-process
//! tuning — the tune-once/serve-many artifact story.

use std::path::PathBuf;
use std::process::Command;

use parframe::api::{Plan, PlanTier, Session, Workload};
use parframe::config::CpuPlatform;
use parframe::sched::LanePlan;
use parframe::tuner::Baseline;
use parframe::PallasError;

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("parframe_{}_{name}", std::process::id()))
}

/// serialize → parse must be the identity, and serialization a fixed
/// point, for a plan from any tier.
fn assert_roundtrip_identity(plan: &Plan) {
    let text = plan.to_json();
    let back = Plan::from_json(&text).unwrap_or_else(|e| panic!("{}: {e}", plan.tier.name()));
    assert_eq!(&back, plan, "round-trip changed the plan ({})", plan.tier.name());
    assert_eq!(back.to_json(), text, "serialization not a fixed point");
    // latency bits survive exactly (f64 → shortest decimal → f64)
    for (a, b) in plan.entries.iter().zip(&back.entries) {
        assert_eq!(a.predicted_latency_s.to_bits(), b.predicted_latency_s.to_bits());
    }
}

#[test]
fn roundtrip_identity_for_every_tier() {
    let session = Session::on(CpuPlatform::small());
    let single = Workload::single("wide_deep").unwrap();
    let mix = Workload::mix(&[("wide_deep", 0.7), ("resnet50", 0.3)]).unwrap();

    assert_roundtrip_identity(&session.tune(&single).unwrap());
    assert_roundtrip_identity(&session.tune(&mix).unwrap());
    assert_roundtrip_identity(&session.tune_exhaustive(&single).unwrap());
    for b in Baseline::ALL {
        assert_roundtrip_identity(&session.tune_baseline(&mix, b).unwrap());
    }
    // online-snapshot tier via a live core-aware deployment
    let handle = session.serve_guideline(&mix).unwrap();
    let snap = session.snapshot(&handle).unwrap();
    assert_eq!(snap.tier, PlanTier::OnlineSnapshot);
    assert_roundtrip_identity(&snap);
}

#[test]
fn roundtrip_identity_across_the_zoo() {
    // property-style sweep: the guideline plan of every zoo model
    // round-trips exactly (covers every policy/parallelism combination
    // the width rule can produce)
    let session = Session::on(CpuPlatform::large2());
    for name in parframe::models::model_names() {
        let w = Workload::single(name).unwrap();
        let plan = session.tune(&w).unwrap();
        assert_roundtrip_identity(&plan);
        plan.verify_fingerprint(session.platform()).unwrap();
    }
}

#[test]
fn file_roundtrip_preserves_plan() {
    let session = Session::on(CpuPlatform::large2());
    let plan = session
        .tune(&Workload::mix(&[("transformer", 0.5), ("resnet50", 0.5)]).unwrap())
        .unwrap();
    let path = tmp_path("file_roundtrip.json");
    plan.save(path.to_str().unwrap()).unwrap();
    let loaded = Plan::load(path.to_str().unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, plan);
}

#[test]
fn serve_from_loaded_plan_is_bit_identical_to_in_process() {
    // the acceptance bar: tune → emit → load → serve must produce the
    // same latency tables, bit for bit, as serving the in-process plan
    let workload = Workload::mix(&[("wide_deep", 0.6), ("resnet50", 0.4)]).unwrap();
    let tuned = Session::on(CpuPlatform::large2());
    let plan = tuned.tune(&workload).unwrap();

    let path = tmp_path("serve_bitident.json");
    plan.save(path.to_str().unwrap()).unwrap();
    let loaded = Plan::load(path.to_str().unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, plan);

    // fresh sessions (fresh caches) on both sides: nothing shared but
    // the artifact bits
    let table_a = Session::on(CpuPlatform::large2())
        .serve(&plan)
        .unwrap()
        .latency_table()
        .unwrap();
    let table_b = Session::on(CpuPlatform::large2())
        .serve(&loaded)
        .unwrap()
        .latency_table()
        .unwrap();
    assert_eq!(table_a.len(), table_b.len());
    assert!(!table_a.is_empty());
    for ((ka, la), (kb, lb)) in table_a.iter().zip(&table_b) {
        assert_eq!(ka, kb);
        assert_eq!(la.to_bits(), lb.to_bits(), "{ka:?}: {la} != {lb}");
    }
}

#[test]
fn cross_process_emit_plan_matches_in_process_tuning() {
    // run the real binary: `tune --emit-plan` in a child process, then
    // load the artifact here and compare against in-process tuning —
    // equality is bitwise (configs, layout, predicted-latency f64s)
    let path = tmp_path("cross_process.json");
    let out = Command::new(env!("CARGO_BIN_EXE_parframe"))
        .args([
            "tune",
            "--model",
            "wide_deep",
            "--platform",
            "large.2",
            "--emit-plan",
            path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn parframe tune");
    assert!(
        out.status.success(),
        "tune failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let emitted = Plan::load(path.to_str().unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);

    let in_process =
        Session::on(CpuPlatform::large2()).tune(&Workload::single("wide_deep").unwrap()).unwrap();
    assert_eq!(emitted, in_process, "cross-process plan differs from in-process tuning");

    // and the loaded artifact deploys: same tables as the in-process plan
    let served = Session::on(CpuPlatform::large2()).serve(&emitted).unwrap();
    let t_emitted = served.latency_table().unwrap();
    let t_inproc = Session::on(CpuPlatform::large2())
        .serve(&in_process)
        .unwrap()
        .latency_table()
        .unwrap();
    for ((ka, la), (kb, lb)) in t_emitted.iter().zip(&t_inproc) {
        assert_eq!(ka, kb);
        assert_eq!(la.to_bits(), lb.to_bits());
    }
}

#[test]
fn cli_rejects_unknown_flags_listing_accepted() {
    // the flag-parser satellite: a misspelled flag must fail loudly and
    // name the accepted flags, not silently drop
    let out = Command::new(env!("CARGO_BIN_EXE_parframe"))
        .args(["tune", "--model", "wide_deep", "--job", "8"])
        .output()
        .expect("spawn parframe");
    assert!(!out.status.success(), "misspelled --job must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--job"), "error must name the bad flag: {err}");
    assert!(err.contains("--jobs"), "error must list accepted flags: {err}");
}

#[test]
fn serve_checks_platform_and_fingerprint() {
    let tuned = Session::on(CpuPlatform::large2());
    let plan = tuned.tune(&Workload::single("ncf").unwrap()).unwrap();

    // wrong platform → PlanMismatch naming both sides
    match Session::on(CpuPlatform::large()).serve(&plan) {
        Err(PallasError::PlanMismatch { expected_platform, got }) => {
            assert_eq!(expected_platform, "large.2");
            assert_eq!(got, "large");
        }
        other => panic!("expected PlanMismatch, got {:?}", other.err()),
    }

    // tampered fingerprint → InvalidPlan
    let mut stale = plan.clone();
    stale.sim_fingerprint ^= 1;
    assert!(matches!(
        Session::on(CpuPlatform::large2()).serve(&stale),
        Err(PallasError::InvalidPlan(_))
    ));
}

#[test]
fn snapshot_plan_redeploys() {
    // an online-snapshot artifact is itself deployable: snapshot a live
    // deployment, round-trip it, serve it again
    let session = Session::on(CpuPlatform::large());
    let w = Workload::kinds(&["wide_deep", "ncf"]).unwrap();
    let handle = session.serve_guideline(&w).unwrap();
    let snap = session.snapshot(&handle).unwrap();
    drop(handle);
    let restored = Plan::from_json(&snap.to_json()).unwrap();
    let lane_plan: LanePlan = restored.lane_plan(session.platform()).unwrap();
    lane_plan.validate().unwrap();
    let handle2 = Session::on(CpuPlatform::large()).serve(&restored).unwrap();
    let report = handle2.run_closed("wide_deep", 32, 4).unwrap();
    assert_eq!(report.errors, 0);
    assert!(report.completed >= 32);
}
