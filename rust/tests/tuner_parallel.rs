//! Equivalence properties for the tuning-throughput subsystem: the
//! parallel, memoized sweep executor must be **bit-identical** to the
//! serial uncached path it replaced — same best config, same latency
//! bits, same unique-point count — at any `--jobs` value, with a cold
//! or warm cache, for the exhaustive tuner and the sim backend's
//! latency tables alike.

use std::sync::Arc;

use parframe::config::{CpuPlatform, FrameworkConfig, SchedPolicy};
use parframe::models;
use parframe::runtime::{BackendFactory, SimBackendConfig, SimBackendFactory};
use parframe::sched::LanePlan;
use parframe::sim::{self, PreparedGraph, SimCache, SimOptions};
use parframe::tuner::{exhaustive_search_with, lattice, OnlineTuner, SweepOptions};

const ZOO: [&str; 3] = ["wide_deep", "ncf", "squeezenet"];

fn platforms() -> [CpuPlatform; 2] {
    [CpuPlatform::small(), CpuPlatform::large2()]
}

/// The reference implementation: the seed's serial, uncached sweep —
/// plain `sim::simulate` over the lattice in order, strict `<` keeps
/// the earliest point on ties.
fn serial_uncached_sweep(
    graph: &parframe::graph::Graph,
    platform: &CpuPlatform,
) -> (FrameworkConfig, f64, usize) {
    let points = lattice(platform);
    let mut best: Option<(FrameworkConfig, f64)> = None;
    for cfg in points.iter() {
        let lat = sim::simulate(graph, platform, cfg).unwrap().latency_s;
        if best.as_ref().map_or(true, |(_, b)| lat < *b) {
            best = Some((cfg.clone(), lat));
        }
    }
    let (cfg, lat) = best.expect("non-empty lattice");
    (cfg, lat, points.len())
}

#[test]
fn parallel_cached_sweep_bit_identical_to_serial_uncached() {
    for platform in platforms() {
        for name in ZOO {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let (ref_cfg, ref_lat, ref_points) = serial_uncached_sweep(&g, &platform);
            let shared = Arc::new(SimCache::new());
            for jobs in [1usize, 4] {
                // cold private cache, then the shared (warming) cache:
                // first pass simulates, later passes mostly hit — the
                // result bits must never move
                for cache in [Arc::new(SimCache::new()), Arc::clone(&shared)] {
                    let r = exhaustive_search_with(
                        &g,
                        &platform,
                        &SweepOptions::shared(jobs, cache),
                    )
                    .unwrap();
                    let tag = format!("{name}/{}/jobs={jobs}", platform.name);
                    assert_eq!(r.best, ref_cfg, "{tag}: best config diverged");
                    assert_eq!(
                        r.best_latency_s.to_bits(),
                        ref_lat.to_bits(),
                        "{tag}: latency bits diverged"
                    );
                    assert_eq!(r.evaluated, ref_points, "{tag}: unique-point count diverged");
                }
            }
            // by the final sweep the shared cache has seen every point
            assert!(shared.hits() > 0, "{name}: warm cache never hit");
        }
    }
}

#[test]
fn prepared_simulation_matches_direct() {
    // the prepared fast path reuses precomputed ranks/weights/CSR/flags;
    // it must reproduce the direct engine bit-for-bit for every policy
    let p = CpuPlatform::large2();
    for name in ["inception_v2", "transformer", "resnet50"] {
        let g = models::build(name, 8).unwrap();
        let prep = PreparedGraph::new(&g);
        for policy in SchedPolicy::ALL {
            let mut cfg = FrameworkConfig::tuned_default();
            cfg.inter_op_pools = 3;
            cfg.mkl_threads = 16;
            cfg.intra_op_threads = 16;
            cfg.sched_policy = policy;
            let direct = sim::simulate(&g, &p, &cfg).unwrap();
            let via = sim::simulate_prepared(&prep, &p, &cfg, &SimOptions::default()).unwrap();
            let tag = format!("{name}/{policy:?}");
            assert_eq!(direct.latency_s.to_bits(), via.latency_s.to_bits(), "{tag}");
            assert_eq!(direct.upi_bytes.to_bits(), via.upi_bytes.to_bits(), "{tag}");
            assert_eq!(direct.upi_peak_bps.to_bits(), via.upi_peak_bps.to_bits(), "{tag}");
            assert_eq!(direct.gflops.to_bits(), via.gflops.to_bits(), "{tag}");
        }
    }
}

#[test]
fn backend_tables_bit_identical_across_jobs_and_cache() {
    // SimBackend latency-table construction: per-bucket tuned and
    // policy-pinned variants, jobs=1 vs jobs=4, fresh backend each time
    // (i.e. cold caches) — every (kind, bucket) latency must match bits
    let kinds = ["wide_deep", "transformer"];
    for policy in [None, Some(SchedPolicy::Topo)] {
        let table = |jobs: usize| -> Vec<u64> {
            let mut cfg = SimBackendConfig::new(CpuPlatform::large2(), &kinds);
            cfg.jobs = jobs;
            cfg.policy = policy;
            let b = parframe::runtime::SimBackend::new(cfg).unwrap();
            kinds
                .iter()
                .flat_map(|k| {
                    [1usize, 2, 4, 8]
                        .iter()
                        .map(|&bk| b.simulated_latency(k, bk).unwrap().to_bits())
                        .collect::<Vec<_>>()
                })
                .collect()
        };
        let serial = table(1);
        let parallel = table(4);
        assert_eq!(serial, parallel, "policy={policy:?}");
    }
}

#[test]
fn online_and_backend_tiers_share_an_injected_cache() {
    // the `serve --adaptive` wiring: the backend factory and the online
    // tuner hold ONE cache, so scoring the live plan at a bucket the
    // lane tables already simulated is pure cache hits — no re-plan
    // cold-start re-simulation
    let platform = CpuPlatform::large2();
    let kinds = ["wide_deep", "resnet50"];
    let plan = LanePlan::guideline(&platform, &kinds).unwrap();
    let cache = Arc::new(SimCache::new());
    let factory = SimBackendFactory::with_cache(
        SimBackendConfig::new(platform.clone(), &kinds),
        Arc::clone(&cache),
    );
    for a in plan.lane_assignments() {
        factory.create_on(&a).unwrap();
    }
    let misses = cache.misses();
    assert!(misses > 0);
    let tuner = OnlineTuner::new(platform, &kinds).with_cache(Arc::clone(&cache));
    let score = tuner.score(&plan);
    assert!(score.is_finite() && score > 0.0);
    assert_eq!(cache.misses(), misses, "cross-tier score re-simulated cached points");
}

#[test]
fn cross_tier_dedupe_through_a_shared_cache() {
    // the same design points scored by two tiers through one cache run
    // once: a second identical sweep is pure hits
    let g = models::build("ncf", models::canonical_batch("ncf")).unwrap();
    let p = CpuPlatform::small();
    let cache = Arc::new(SimCache::new());
    let first = exhaustive_search_with(
        &g,
        &p,
        &SweepOptions::shared(2, Arc::clone(&cache)).prune(false),
    )
    .unwrap();
    let misses_after_first = cache.misses();
    assert_eq!(misses_after_first as usize, first.evaluated);
    assert_eq!(first.simulated, first.evaluated, "a flat sweep simulates every point");
    // the re-sweep keeps branch-and-bound on: whatever subset it decides
    // to simulate, the warm cache must already hold it
    let second =
        exhaustive_search_with(&g, &p, &SweepOptions::shared(4, Arc::clone(&cache))).unwrap();
    assert_eq!(cache.misses(), misses_after_first, "re-sweep must be pure cache hits");
    assert_eq!(first.best, second.best);
    assert_eq!(first.best_latency_s.to_bits(), second.best_latency_s.to_bits());
}
